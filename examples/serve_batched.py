"""Serve a small model with batched requests through the slot engine,
mixing prompt lengths — exercises batched prefill-into-slot admission plus
the fused block-decode loop (``decode_block`` tokens per host iteration,
per-slot positions, one device->host sync per block).

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import reduced
from repro.core.registry import get
from repro.models.lm import init_lm_params
from repro.serving.engine import Request, ServingEngine

cfg = reduced(get("zamba2-2.7b"))
params = init_lm_params(cfg, jax.random.PRNGKey(0))
eng = ServingEngine(cfg, params, slots=4, max_seq=160, decode_block=8)

rng = np.random.default_rng(7)
for i in range(10):
    plen = int(rng.integers(8, 64))
    eng.submit(Request(rid=i,
                       prompt=rng.integers(2, cfg.vocab_size,
                                           plen).astype(np.int32),
                       max_new=int(rng.integers(4, 12))))
t0 = time.perf_counter()
done = eng.run()
dt = time.perf_counter() - t0
toks = sum(len(r.out) for r in done)
print(f"{len(done)} requests, {toks} new tokens in {dt:.1f}s "
      f"({toks / dt:.1f} tok/s, block={eng.decode_block})")
for r in sorted(done, key=lambda r: r.rid)[:3]:
    print(f"  rid={r.rid} out={r.out}")
assert len(done) == 10
assert all(len(r.out) >= r.max_new for r in done)
print("OK")
