"""End-to-end driver: train a ~100M-param hybrid LM for a few hundred
steps on the synthetic needle-retrieval pipeline, with checkpointing and
restart — then verify the restart resumes identically.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import os
import tempfile

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--big", action="store_true",
                help="~100M-param config (slower per step on CPU)")
args = ap.parse_args()

# zamba2-style hybrid (mamba2 backbone + shared attention): 25M default for
# a fast single-core run; --big = the ~100M configuration.  vocab kept
# small so the needle-retrieval stream is learnable within a few hundred
# steps (the CE floor for random tokens is ln(vocab)).
d_model = 1024 if args.big else 512
CFG = ModelConfig(
    name="hybrid-100m" if args.big else "hybrid-25m", family="hybrid",
    n_layers=12, d_model=d_model, d_ff=0,
    vocab_size=1024,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2, chunk=64),
    layer_pattern=("mamba2", "mamba2", "mamba2+shared"),
    shared_attn=AttnConfig(n_heads=8, n_kv_heads=8, head_dim=d_model // 8),
    shared_attn_d_ff=4 * d_model, tie_embeddings=False)
print(f"params: {CFG.param_count() / 1e6:.1f}M", flush=True)

ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_lm_ckpt")
trainer = Trainer(CFG, OptConfig(lr=3e-3, warmup_steps=30),
                  TrainerConfig(steps=args.steps, ckpt_every=100,
                                ckpt_dir=ckpt_dir, log_every=20),
                  seq_len=args.seq, global_batch=args.batch)
if trainer.maybe_restore():
    print(f"[fault-tolerance] resumed from step {trainer.state.step}")
state = trainer.run(log=lambda m: print(m, flush=True))
first = float(np.mean(state.losses[:20]))
last = float(np.mean(state.losses[-20:]))
print(f"loss: first-20 mean {first:.4f} -> last-20 mean {last:.4f}; "
      f"stragglers={state.straggler_steps}")
assert last < first - 0.01, "training did not learn"
print("OK")
