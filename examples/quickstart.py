"""Quickstart: build a model from the registry, run forward / prefill /
decode, and characterize it with the paper's flow — all on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import reduced
from repro.core.config import RTX_4090
from repro.core.hlo_analysis import analyze_compiled
from repro.core.registry import get, list_archs
from repro.core.roofline import op_class_times
from repro.models.lm import init_lm_params, lm_forward
from repro.serving.engine import greedy_generate

print("registered architectures:", ", ".join(list_archs()))

# 1. pick an arch (reduced for CPU) and run it
cfg = reduced(get("mamba2-2.7b"))
params = init_lm_params(cfg, jax.random.PRNGKey(0))
tokens = jnp.ones((2, 64), jnp.int32)
logits = jax.jit(lambda p, t: lm_forward(cfg, p, {"tokens": t},
                                         train=False))(params, tokens)
print(f"forward: logits {logits.shape}")

# 2. generate with the serving path: prefill + the fused decode loop —
# the whole 8-token burst is ONE compiled program (lax.scan over
# lm_decode_step, on-device argmax, zero host syncs per token)
out, _ = greedy_generate(cfg, params, {"tokens": tokens}, max_seq=96,
                         gen_len=8)
print(f"generated: {out.shape} -> {out[0].tolist()}")

# 3. the paper's characterization flow: compile -> operator-class breakdown
compiled = jax.jit(
    lambda p, t: lm_forward(cfg, p, {"tokens": t}, train=False)
).lower(params, tokens).compile()
cost = analyze_compiled(compiled)
times = op_class_times(cost, RTX_4090)
total = sum(times.values())
print("operator-class latency shares (RTX 4090 time model):")
for clazz, t in sorted(times.items(), key=lambda kv: -kv[1]):
    print(f"  {clazz:12s} {100 * t / total:5.1f}%")
