"""The paper's end-to-end characterization flow (Fig. 4) as a script:
sweep sequence lengths for a Transformer vs an SSM, report the memory
frontier, TTFT model, and operator breakdown — the Fig. 1/5/7 story.

  PYTHONPATH=src python examples/characterize.py
"""
import sys

sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

from benchmarks.common import class_times, cost_for, time_on  # noqa: E402
from repro.core.config import RTX_4090                         # noqa: E402
from repro.core.memmodel import inference_memory, max_seq_len  # noqa: E402
from repro.core.registry import get                            # noqa: E402

TF, SSM = "qwen2.5-0.5b", "mamba2-780m"

print(f"{'seq':>8} | {'TTFT ' + TF:>18} | {'TTFT ' + SSM:>18} | winner")
for seq in (1024, 4096, 16384, 32768):
    t1 = time_on(cost_for(TF, "prefill", seq), RTX_4090)
    t2 = time_on(cost_for(SSM, "prefill", seq), RTX_4090)
    w = TF if t1 < t2 else SSM
    print(f"{seq:>8} | {t1 * 1e3:>15.1f}ms | {t2 * 1e3:>15.1f}ms | {w}")

print("\nmemory @32K:",
      f"{TF}: {inference_memory(get(TF), 1, 32768).total / 1e9:.2f} GB,",
      f"{SSM}: {inference_memory(get(SSM), 1, 32768).total / 1e9:.2f} GB")
print("OOM frontier (24GB):",
      f"{TF}: {max_seq_len(get(TF), 24e9):,},",
      f"{SSM}: {max_seq_len(get(SSM), 24e9):,}")

print(f"\noperator-class shares for {SSM} @16K (RTX 4090):")
ct = class_times(cost_for(SSM, "prefill", 16384), RTX_4090)
tot = sum(ct.values())
for k, v in sorted(ct.items(), key=lambda kv: -kv[1]):
    print(f"  {k:12s} {100 * v / tot:5.1f}%")
