#!/usr/bin/env python
"""Docs freshness gate: fail when docs reference code that no longer exists.

Scans every ``docs/*.md`` (plus ``benchmarks/README.md``) for

  * repo paths — ``src/repro/...``, ``scripts/...``, ``benchmarks/...``,
    ``tests/...`` — and fails if the file or directory is gone;
  * ``REPRO_*`` environment variables, and fails if the variable is no
    longer read anywhere under ``src/`` or ``scripts/``;
  * ``BENCH_*.json`` trajectory records, and fails if the file is gone.

It also validates the REVERSE direction for environment variables: every
``REPRO_*`` variable actually read under ``src/`` or ``scripts/`` must
have a row in ``docs/CONFIGURATION.md`` — adding a knob without
documenting it fails verify.sh (this is how REPRO_PROFILE,
REPRO_METRICS_PATH and REPRO_TELEMETRY_WARMSTART stay documented).

This keeps the docs subsystem from rotting silently: renaming a module,
deleting an env var, or retiring a trajectory breaks verify.sh until the
docs are updated.  References may carry a ``:symbol`` suffix
(``src/repro/models/lm.py:lm_prefill_chunk``) — only the path part is
checked.

  python scripts/check_docs.py          # exits 1 with a report on stale refs
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = sorted(ROOT.glob("docs/*.md")) + [ROOT / "benchmarks" / "README.md"]

PATH_RE = re.compile(
    r"\b((?:src/repro|scripts|benchmarks|tests|docs)/[A-Za-z0-9_./-]+)")
ENV_RE = re.compile(r"\b(REPRO_[A-Z0-9_]+)\b")
BENCH_RE = re.compile(r"\b(BENCH_[A-Za-z0-9_]+\.json)\b")


def _env_vars_in_tree() -> set:
    # src/ and scripts/ only, matching the failure message: a stale
    # mention in a test or benchmark comment must not keep a deleted
    # runtime variable "documented"
    found = set()
    for base in ("src", "scripts"):
        for f in (ROOT / base).rglob("*"):
            if f.suffix in (".py", ".sh") and f.is_file():
                found.update(ENV_RE.findall(f.read_text(errors="ignore")))
    return found


def main() -> int:
    if not DOC_FILES:
        print("check_docs: no docs/*.md found", file=sys.stderr)
        return 1
    tree_envs = _env_vars_in_tree()
    stale = []
    checked_paths = checked_envs = 0
    for doc in DOC_FILES:
        if not doc.exists():
            continue
        text = doc.read_text()
        rel = doc.relative_to(ROOT)
        for m in PATH_RE.finditer(text):
            # strip trailing punctuation the prose may attach, and any
            # :symbol suffix
            path = m.group(1).rstrip(".,;:)`'\"").split(":")[0]
            checked_paths += 1
            if not (ROOT / path).exists():
                stale.append(f"{rel}: path `{path}` does not exist")
        for var in set(ENV_RE.findall(text)):
            checked_envs += 1
            if var == "REPRO_":                     # prose artifact guard
                continue
            if var not in tree_envs:
                stale.append(
                    f"{rel}: env var `{var}` is not read anywhere under "
                    "src/ or scripts/")
        for rec in set(BENCH_RE.findall(text)):
            if not (ROOT / rec).exists():
                stale.append(f"{rel}: trajectory record `{rec}` is missing")
    # reverse direction: every env var the runtime reads must have a row
    # in docs/CONFIGURATION.md
    config_doc = ROOT / "docs" / "CONFIGURATION.md"
    documented = (set(ENV_RE.findall(config_doc.read_text()))
                  if config_doc.exists() else set())
    for var in sorted(tree_envs - documented):
        stale.append(
            f"docs/CONFIGURATION.md: env var `{var}` is read under src/ "
            "or scripts/ but has no documentation row")
    if stale:
        print("check_docs FAILED — stale references:", file=sys.stderr)
        for s in stale:
            print(f"  {s}", file=sys.stderr)
        return 1
    print(f"check_docs OK: {len(DOC_FILES)} docs, {checked_paths} path refs, "
          f"{checked_envs} env refs verified")
    return 0


if __name__ == "__main__":
    sys.exit(main())
