#!/usr/bin/env python
"""Injectable-clock lint for the serving subsystem.

Every module under ``src/repro/serving/`` times through the one
injectable clock the engine threads everywhere (``clock=`` ctor
parameters defaulting to ``time.monotonic``) — that is what lets the
fault/scheduling tests and the scheduling smoke drive a deterministic
fake clock and assert on deadlines, starvation bounds, and TTFTs
without wall-time noise.  A direct ``time.perf_counter()`` or
``time.time()`` call inside serving code bypasses the injection and
silently reintroduces host-load jitter into "deterministic" runs, so
this lint fails the build on any such call (or on importing those names
from ``time``).  ``time.monotonic`` is allowed **as a default** for an
injectable parameter; calling it directly at a timing site is flagged
too — read ``self._clock`` instead.

  python scripts/check_clock.py         # exits 1 with file:line per violation
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SERVING = ROOT / "src" / "repro" / "serving"

#: time.* attributes that read a wall/CPU clock; calling any of these
#: directly in serving code bypasses the injectable clock
BANNED_CALLS = {"perf_counter", "perf_counter_ns", "time", "time_ns",
                "monotonic", "monotonic_ns", "process_time",
                "process_time_ns"}


def _violations(path: Path) -> list:
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []
    for node in ast.walk(tree):
        # from time import perf_counter  (any clock name)
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in BANNED_CALLS:
                    out.append((node.lineno,
                                f"from time import {alias.name}"))
        # time.<clock>() called directly — a bare `time.monotonic`
        # reference (no call) stays legal as an injectable default
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "time"
                    and f.attr in BANNED_CALLS):
                out.append((node.lineno, f"time.{f.attr}() call"))
    return out


def main() -> int:
    bad = []
    for path in sorted(SERVING.glob("*.py")):
        for lineno, what in _violations(path):
            bad.append(f"{path.relative_to(ROOT)}:{lineno}: {what} "
                       "bypasses the injectable clock (accept a clock= "
                       "parameter defaulting to time.monotonic instead)")
    if bad:
        print("check_clock: serving code must time through the "
              "injectable clock:")
        for line in bad:
            print(f"  {line}")
        return 1
    files = len(list(SERVING.glob("*.py")))
    print(f"check_clock OK: {files} serving modules, no direct "
          "wall-clock calls")
    return 0


if __name__ == "__main__":
    sys.exit(main())
