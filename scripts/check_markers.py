#!/usr/bin/env python
"""Slow-marker gate: fail when a test that takes real wall time is not
marked ``@pytest.mark.slow``.

The tier-1 suite (``python -m pytest -x -q``) must stay fast enough to
run on every change; anything expensive belongs behind the ``slow``
marker so plain runs skip it (``REPRO_RUN_SLOW=1`` opts back in, and
verify.sh always does).  This script closes the loop: verify.sh runs
pytest with ``--junitxml`` and then feeds the report here.  Any testcase
whose recorded wall time exceeds the threshold (default 20s, override
with ``REPRO_SLOW_THRESHOLD_S``) and that is NOT collected under
``-m slow`` fails the gate — an expensive test can land, but not
unmarked, where it would silently tax every tier-1 run forever.

Skipped testcases are exempt (their recorded time is setup-only), and a
missing junit report is an error, not a pass — the gate must not
green-light a run it never saw.

  python scripts/check_markers.py --junit /tmp/junit.xml
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import xml.etree.ElementTree as ET
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def slow_marked_keys() -> set:
    """(classname, name) keys of every test collected under ``-m slow``.

    Uses pytest's own collector rather than grepping for decorators so
    indirect marking (``pytestmark``, ``config.addinivalue_line``,
    parametrized ids) is honoured.
    """
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         "-m", "slow"],
        cwd=ROOT, env=env, capture_output=True, text=True)
    keys = set()
    for line in out.stdout.splitlines():
        line = line.strip()
        if "::" not in line:
            continue
        parts = line.split("::")
        module = parts[0][:-3].replace("/", ".")  # tests/foo.py -> tests.foo
        classname = ".".join([module] + parts[1:-1])
        name = parts[-1]
        keys.add((classname, name))
        # junit strips parametrize brackets from classname but keeps
        # them in name; collect-only keeps them in name already, so the
        # raw key matches — also index the bare name for safety
        if "[" in name:
            keys.add((classname, name.split("[", 1)[0]))
    return keys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--junit", required=True,
                    help="junit XML report from the tier-1 pytest run")
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get(
                        "REPRO_SLOW_THRESHOLD_S", "20")),
                    help="wall seconds above which a test must carry "
                         "@pytest.mark.slow (default 20, env "
                         "REPRO_SLOW_THRESHOLD_S)")
    args = ap.parse_args()

    junit = Path(args.junit)
    if not junit.is_file():
        print(f"check_markers: junit report {junit} not found — run "
              "pytest with --junitxml first", file=sys.stderr)
        return 1
    try:
        root = ET.parse(junit).getroot()
    except ET.ParseError as e:
        print(f"check_markers: junit report unparsable: {e}",
              file=sys.stderr)
        return 1

    slow = slow_marked_keys()
    offenders = []
    checked = 0
    for case in root.iter("testcase"):
        if case.find("skipped") is not None:
            continue
        checked += 1
        t = float(case.get("time") or 0.0)
        if t <= args.threshold:
            continue
        classname = case.get("classname") or ""
        name = case.get("name") or ""
        key = (classname, name)
        bare = (classname, name.split("[", 1)[0])
        if key in slow or bare in slow:
            continue
        offenders.append((t, classname, name))

    for t, classname, name in sorted(offenders, reverse=True):
        print(f"check_markers: {classname}::{name} took {t:.1f}s "
              f"(> {args.threshold:g}s) without @pytest.mark.slow",
              file=sys.stderr)
    if offenders:
        print(f"check_markers: {len(offenders)} unmarked slow test(s) — "
              "mark them @pytest.mark.slow or speed them up",
              file=sys.stderr)
        return 1
    print(f"check_markers OK: {checked} testcases, none over "
          f"{args.threshold:g}s unmarked ({len(slow)} slow-marked "
          "collected)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
