#!/usr/bin/env bash
# Tier-1 gate + decode perf smoke in one command:
#   bash scripts/verify.sh
# Runs the tier-1 pytest command, then the decode perf smoke, and fails
# if either failed (the smoke still runs when pre-existing tests fail,
# so the perf trajectory is always recorded).
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
tier1=$?

python benchmarks/decode_bench.py --smoke
smoke=$?

echo "tier1=$tier1 decode_smoke=$smoke"
exit $(( tier1 || smoke ))
