#!/usr/bin/env bash
# Tier-1 gate + decode/prefill/attn perf smokes + docs check in one command:
#   bash scripts/verify.sh
# Runs the tier-1 pytest command WITH the slow kernel-parity sweeps
# (REPRO_RUN_SLOW=1 — tier-1 alone keeps only the thin parity smokes to
# stay inside the CI container's 5-minute budget), then the decode perf
# smoke (fused loop >= 2x the per-token loop), the prefill smoke (chunked
# peak-activation memory < one-shot at 8K+ prompts for every config row —
# the windowed ring-buffer row included — TTFT regression bound,
# interleaving fairness 1.0), the attention smoke (per-chunk attention
# time tracks the live prefix under KV bucketing, flash-decode parity,
# chunked-prefill parity), the fault smoke (divergence sentinels +
# periodic checkpointing < 5% overhead on the healthy path, NaN recovery
# replays bit-identically), the restart smoke (a killed engine recovers
# from the durable checkpoint store bit-identically with recovery wall
# < 20% of redo-from-scratch), and the docs freshness check (paths /
# REPRO_* vars named in docs/*.md must exist AND every REPRO_* var the
# runtime reads is documented — see docs/CONFIGURATION.md for the
# thresholds), and fails if any failed (the smokes still run when
# pre-existing tests fail, so the perf trajectories are always recorded).
# check_markers.py reads the tier-1 junit report and fails if any test
# over the wall-time threshold (REPRO_SLOW_THRESHOLD_S, default 20s)
# lacks @pytest.mark.slow.
#
# The decode smoke carries the PROFILER gates: measured kernel-family
# shares (jax.profiler trace sweep) must sum to 1, the ssm family must
# hold the plurality at the longest profiled context for the SSM and
# hybrid profiling configs, and the coarse-mode profiler's bookkeeping
# overhead on the serving decode path must stay < 3% of decode wall.
# It also carries the SCHEDULING gates: per-request outputs bit-identical
# across fifo/strict_tiers/weighted_fair, Jain fairness >= 0.8 for
# weighted_fair under sustained backlog, and the starvation bound
# honored.  check_clock.py lints src/repro/serving/ for direct
# time.perf_counter/time.time calls that would bypass the injectable
# clock those deterministic gates rely on.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

junit="$(mktemp -t repro-junit-XXXXXX.xml)"
REPRO_RUN_SLOW=1 python -m pytest -x -q --junitxml "$junit"
tier1=$?

python benchmarks/decode_bench.py --smoke
smoke=$?

python benchmarks/prefill_bench.py --smoke
prefill=$?

python benchmarks/attn_bench.py --smoke
attn=$?

python benchmarks/decode_bench.py --faults
faults=$?

python benchmarks/decode_bench.py --restart
restart=$?

python scripts/check_docs.py
docs=$?

python scripts/check_clock.py
clock=$?

python scripts/check_markers.py --junit "$junit"
markers=$?
rm -f "$junit"

echo "tier1=$tier1 decode_smoke=$smoke prefill_smoke=$prefill attn_smoke=$attn fault_smoke=$faults restart_smoke=$restart docs_check=$docs clock_lint=$clock marker_check=$markers"
exit $(( tier1 || smoke || prefill || attn || faults || restart || docs || clock || markers ))
