#!/usr/bin/env bash
# Tier-1 gate + decode/prefill perf smokes in one command:
#   bash scripts/verify.sh
# Runs the tier-1 pytest command, then the decode perf smoke (fused loop
# >= 2x the per-token loop) and the prefill smoke (chunked peak-activation
# memory < one-shot at 8K+ prompts, TTFT regression bound, interleaving
# fairness 1.0), and fails if any failed (the smokes still run when
# pre-existing tests fail, so the perf trajectories are always recorded).
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
tier1=$?

python benchmarks/decode_bench.py --smoke
smoke=$?

python benchmarks/prefill_bench.py --smoke
prefill=$?

echo "tier1=$tier1 decode_smoke=$smoke prefill_smoke=$prefill"
exit $(( tier1 || smoke || prefill ))
