"""Render the paper's figures as ASCII charts into benchmarks/results/figures.md
(the open-source characterization tool's report output).

  PYTHONPATH=src python -m benchmarks.figures
"""
from __future__ import annotations

import os

from repro.core.config import JETSON_ORIN_NANO, RTX_4090
from repro.core.memmodel import inference_memory
from repro.core.registry import get
from benchmarks.common import RESULTS_DIR, class_times, cost_for, time_on

BAR = 46
ORDER = ("ssm", "gemm", "norm", "arith", "memory", "other")


def _bar(frac: float, width: int = BAR) -> str:
    n = int(round(frac * width))
    return "█" * n + "·" * (width - n)


def fig1(lines):
    lines.append("\n## Fig. 1 — TTFT scaling (RTX 4090 time model)\n```")
    for seq in (1024, 4096, 8192, 16384, 32768):
        tq = time_on(cost_for("qwen2.5-0.5b", "prefill", seq), RTX_4090)
        tm = time_on(cost_for("mamba2-780m", "prefill", seq), RTX_4090)
        top = max(tq, tm)
        lines.append(f"S={seq:>6}  qwen2.5-0.5b {_bar(tq / top, 30)} {tq * 1e3:8.1f} ms")
        lines.append(f"          mamba2-780m  {_bar(tm / top, 30)} {tm * 1e3:8.1f} ms")
    lines.append("```")


def fig5(lines):
    lines.append("\n## Fig. 5 — memory footprint at context length (24 GB budget)\n```")
    for model in ("qwen2.5-0.5b", "zamba2-1.2b", "falcon-h1-0.5b",
                  "mamba2-780m"):
        cfg = get(model)
        row = [f"{model:16s}"]
        for seq in (8192, 32768, 65536, 131072):
            gb = inference_memory(cfg, 1, seq).total / 1e9
            row.append(f"{'OOM' if gb > 24 else f'{gb:5.1f}G':>7}")
        lines.append(" ".join(row) + "   (S=8K/32K/64K/128K)")
    lines.append("```")


def fig7(lines, model: str, hw, title: str):
    lines.append(f"\n## {title}\n```")
    for seq in (1024, 4096, 16384):
        ct = class_times(cost_for(model, "prefill", seq), hw)
        tot = sum(ct.values()) or 1.0
        segs = []
        for c in ORDER:
            share = ct.get(c, 0.0) / tot
            if share > 0.005:
                segs.append(f"{c}:{100 * share:.0f}%")
        lines.append(f"S={seq:>6}  {_bar(ct.get('ssm', 0) / tot)}  " + " ".join(segs))
    lines.append("```  (bar = SSM-class share)")


def run(em=None) -> None:
    lines = ["# Characterization figures (ASCII render)", ""]
    fig1(lines)
    fig5(lines)
    fig7(lines, "mamba-130m", RTX_4090,
         "Fig. 7a — Mamba-1 130m operator classes (consumer)")
    fig7(lines, "mamba2-130m", RTX_4090,
         "Fig. 7b — Mamba-2 130m operator classes (consumer)")
    fig7(lines, "mamba-130m", JETSON_ORIN_NANO,
         "Fig. 9a — Mamba-1 130m operator classes (edge)")
    fig7(lines, "zamba2-1.2b", RTX_4090,
         "Fig. 8a — Zamba2-1.2B operator classes (consumer)")
    out = os.path.join(RESULTS_DIR, "figures.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    run()
