"""Paper Fig. 6: energy & end-to-end throughput vs sequence length on the
RTX 4090 (Qwen2.5-0.5B vs Mamba2-780m vs Falcon-H1-0.5B).

Claims: at 57K, Transformer 1492 J vs Hybrid 613 J vs SSM 370 J (~75%
reduction, ~4x); Mamba2/Falcon reach 2.64x/1.54x transformer throughput
at 32K."""
from __future__ import annotations

from repro.core.config import RTX_4090
from benchmarks.common import Emitter, cost_for, energy_on, time_on

TRIO = ("qwen2.5-0.5b", "mamba2-780m", "falcon-h1-0.5b")


def run(em: Emitter) -> None:
    e57 = {}
    for m in TRIO:
        c = cost_for(m, "prefill", 57344)
        e57[m] = energy_on(c, RTX_4090)
        em.emit(f"fig6.energy57k.{m}", e57[m] * 1e6,
                f"{e57[m]:.0f}J")
    red = 1 - e57["mamba2-780m"] / e57["qwen2.5-0.5b"]
    em.emit("fig6.claim.energy_reduction", red * 100,
            f"paper~75%_model={red * 100:.0f}%")
    em.emit("fig6.claim.hybrid_between",
            e57["falcon-h1-0.5b"] * 1e6,
            f"ordering={'ok' if e57['mamba2-780m'] < e57['falcon-h1-0.5b'] < e57['qwen2.5-0.5b'] else 'VIOLATED'}")
    # throughput at 32K: prefill + 256 decode steps, batch 1
    thr = {}
    for m in TRIO:
        tp = time_on(cost_for(m, "prefill", 32768), RTX_4090)
        td = time_on(cost_for(m, "decode", 32768), RTX_4090)
        thr[m] = 256 / (tp + 256 * td)
        em.emit(f"fig6.throughput32k.{m}", (tp + 256 * td) * 1e6,
                f"{thr[m]:.1f}tok/s")
    em.emit("fig6.claim.ssm_throughput_x",
            thr["mamba2-780m"] / thr["qwen2.5-0.5b"] * 100,
            f"paper=2.64x_model={thr['mamba2-780m'] / thr['qwen2.5-0.5b']:.2f}x")
    em.emit("fig6.claim.hybrid_throughput_x",
            thr["falcon-h1-0.5b"] / thr["qwen2.5-0.5b"] * 100,
            f"paper=1.54x_model={thr['falcon-h1-0.5b'] / thr['qwen2.5-0.5b']:.2f}x")
