"""Paper Fig. 9: consumer vs edge cross-device comparison.

Claims: on the edge GPU, Mamba-1's SSM ops exceed 55% of latency at every
sequence length; SSM+GEMM >= 75-80% on both devices; for Transformers the
GEMM share DROPS on the edge device (non-GEMM penalty grows)."""
from __future__ import annotations

from repro.core.config import JETSON_ORIN_NANO, RTX_4090
from benchmarks.common import Emitter, class_times, cost_for


def run(em: Emitter) -> None:
    ssm_shares = []
    for seq in (1024, 4096, 8192):
        ct = class_times(cost_for("mamba-130m", "prefill", seq),
                         JETSON_ORIN_NANO)
        tot = sum(ct.values()) or 1.0
        share = ct.get("ssm", 0) / tot
        ssm_shares.append(share)
        ssm_gemm = (ct.get("ssm", 0) + ct.get("gemm", 0)) / tot
        em.emit(f"fig9.edge.mamba-130m.s{seq}", tot * 1e6,
                f"ssm={100 * share:.0f}%_ssm+gemm={100 * ssm_gemm:.0f}%")
    em.emit("fig9.claim.edge_ssm_over_55pct",
            100 * min(ssm_shares),
            f"min_share={100 * min(ssm_shares):.0f}%_paper>55%")
    # transformer GEMM share: consumer vs edge at 1024
    c = class_times(cost_for("qwen2.5-0.5b", "prefill", 1024), RTX_4090)
    e = class_times(cost_for("qwen2.5-0.5b", "prefill", 1024),
                    JETSON_ORIN_NANO)
    gc = c.get("gemm", 0) / (sum(c.values()) or 1)
    ge = e.get("gemm", 0) / (sum(e.values()) or 1)
    em.emit("fig9.claim.transformer_gemm_share_drops_on_edge",
            100 * ge, f"consumer={100 * gc:.0f}%_edge={100 * ge:.0f}%_"
            f"drops={'yes' if ge < gc else 'no'}")
