"""Chunked-prefill benchmark: TTFT vs context length, peak-activation
memory, and prefill/decode interleaving fairness.

For each config and prompt length the bench compares

  * ``oneshot`` — monolithic ``lm_prefill`` over the whole prompt: one
                  O(L) program whose activation footprint grows with L.
  * ``chunked`` — the serving path (``repro.serving.prefill``): the same
                  prompt through the fixed-shape ``lm_prefill_chunk``
                  program ceil(L/chunk) times with state carried between
                  chunks.

reporting TTFT (wall-clock to first token, best-of-iters) and XLA's
compiled temp buffer size (``memory_analysis().temp_size_in_bytes`` —
the peak intermediate-activation allocation of one dispatch).  A second
section runs a mixed serving workload (one long prompt + several short
ones) through ``ServingEngine`` and reports interleaving fairness: the
fraction of engine iterations that ran a prefill chunk alongside live
decode slots in which decode actually emitted tokens (1.0 = no
head-of-line blocking).

Four config rows: ``transformer`` (dense GQA), ``ssm`` (mamba2),
``hybrid`` (mamba2 + shared attention), and ``windowed_hybrid`` (rolling
sliding-window attention + mamba2 — the ring-buffer chunked-prefill path,
prompts many windows long).  All four run the SAME serving pipeline;
there is no separate one-shot path for windowed architectures.

Results append to ``BENCH_prefill.json`` at the repo root.  ``--smoke``
runs the reduced sweep used by ``scripts/verify.sh`` and asserts
  1. chunked peak-activation memory < one-shot at the 8K+ prompt
     (every row, the windowed one included),
  2. chunked TTFT <= TTFT_FACTOR x one-shot (regression bound), and
  3. fairness == 1.0 with all requests completing.

  PYTHONPATH=src python benchmarks/prefill_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_cache, init_lm_params
from repro.serving.bucketing import rope_len_for
from repro.serving.engine import Request, ServingEngine, make_prefill_step
from repro.serving.prefill import _jitted_chunk_step, chunked_prefill
from repro.serving.telemetry import TRACE_SCHEMA_VERSION

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_prefill.json")
TTFT_FACTOR = 2.5   # chunked TTFT bound vs one-shot (CPU dispatch overhead)


def bench_configs(d_model: int = 64):
    # dense_cutoff forces the online-softmax (flash-style) attention core
    # at every length so one-shot vs chunked compares like against like
    attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=d_model // 4,
                      dense_cutoff=1024)
    return [
        ModelConfig(name="transformer", family="dense", n_layers=4,
                    d_model=d_model, d_ff=2 * d_model, vocab_size=256,
                    attn=attn, layer_pattern=("dense",),
                    vocab_pad_multiple=16),
        ModelConfig(name="ssm", family="ssm", n_layers=4, d_model=d_model,
                    d_ff=0, vocab_size=256,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
                    layer_pattern=("mamba2",), vocab_pad_multiple=16),
        ModelConfig(name="hybrid", family="hybrid", n_layers=4,
                    d_model=d_model, d_ff=0, vocab_size=256,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
                    layer_pattern=("mamba2", "mamba2+shared"),
                    shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                           head_dim=d_model // 4,
                                           dense_cutoff=1024),
                    shared_attn_d_ff=2 * d_model, vocab_pad_multiple=16),
        # windowed-hybrid: rolling sliding-window attention + SSM — the
        # ring-buffer chunked-prefill path (prompts are far longer than
        # the window, so every chunk wraps the ring)
        ModelConfig(name="windowed_hybrid", family="hybrid", n_layers=4,
                    d_model=d_model, d_ff=2 * d_model, vocab_size=256,
                    attn=AttnConfig(n_heads=4, n_kv_heads=2,
                                    head_dim=d_model // 4,
                                    sliding_window=512, dense_cutoff=1024),
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
                    layer_pattern=("local", "mamba2"),
                    vocab_pad_multiple=16),
    ]


def _temp_bytes(compiled) -> int:
    try:
        return int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:                                   # pragma: no cover
        return -1


def bench_prefill(cfg, plen: int, chunk: int, max_seq: int,
                  iters: int) -> dict:
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, plen), 0,
                                cfg.vocab_size, jnp.int32)
    template = init_lm_cache(cfg, 1, max_seq)

    # AOT-compile once and reuse the executables for both the memory
    # analysis and the timed runs (no second trace+compile)
    oneshot = jax.jit(make_prefill_step(cfg))
    oneshot_c = oneshot.lower(params, {"tokens": prompt}, template).compile()
    mem_one = _temp_bytes(oneshot_c)

    chunk_step = _jitted_chunk_step(cfg, None)
    ctoks = jnp.zeros((1, chunk), jnp.int32)
    clens = jnp.zeros((1,), jnp.int32)
    # rolling (ring-buffer) caches span only their window: size the rope
    # tables to the serving extent, exactly like ChunkedPrefill does
    chunk_c = chunk_step.lower(params, ctoks, clens, template,
                               rope_len=rope_len_for(cfg, max_seq)).compile()
    mem_chk = _temp_bytes(chunk_c)

    def run_oneshot():
        logits, _ = oneshot_c(params, {"tokens": prompt}, template)
        jax.block_until_ready(logits)

    def run_chunked():
        logits, _ = chunked_prefill(cfg, params, prompt, template,
                                    chunk_size=chunk, step=chunk_c)
        jax.block_until_ready(logits)

    run_oneshot(), run_chunked()                     # warmup
    best_one = best_chk = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run_oneshot()
        best_one = min(best_one, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_chunked()
        best_chk = min(best_chk, time.perf_counter() - t0)
    return {
        "plen": plen, "chunk": chunk,
        "oneshot_ttft_ms": 1e3 * best_one,
        "chunked_ttft_ms": 1e3 * best_chk,
        "ttft_ratio": best_chk / best_one,
        "oneshot_temp_bytes": mem_one,
        "chunked_temp_bytes": mem_chk,
        "mem_ratio": (mem_chk / mem_one) if mem_one > 0 else None,
    }


def bench_interleave(long_len: int, chunk: int) -> dict:
    """Mixed workload through the engine: one long prompt + short prompts;
    decode must progress on every iteration a prefill chunk runs."""
    cfg = bench_configs()[2]                          # hybrid
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    long_p = rng.integers(2, cfg.vocab_size, long_len).astype(np.int32)
    shorts = [rng.integers(2, cfg.vocab_size, 32).astype(np.int32)
              for _ in range(3)]
    eng = ServingEngine(cfg, params, slots=2, max_seq=long_len + 64,
                        decode_block=4, chunk_size=chunk)
    eng.submit(Request(rid=0, prompt=long_p, max_new=8))
    for i, p in enumerate(shorts):
        eng.submit(Request(rid=i + 1, prompt=p, max_new=16))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    st = eng.stats
    fairness = (st["interleave_decode_iters"] / st["interleave_iters"]
                if st["interleave_iters"] else 0.0)
    return {
        "long_len": long_len, "chunk": chunk, "wall_s": wall,
        "completed": len(done), "submitted": 1 + len(shorts),
        "prefill_chunks": st["prefill_chunks"],
        "interleave_iters": st["interleave_iters"],
        "interleave_decode_iters": st["interleave_decode_iters"],
        "fairness": fairness,
        # per-(phase, KV-bucket) latency table — the long prompt walks the
        # whole ladder, so this record carries one entry per rung with
        # compile samples segregated from steady state; the snapshot names
        # its schema version and arch ({"version", "arch", "table"})
        "per_bucket": eng.telemetry.latency_snapshot(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + memory/TTFT/fairness assertions")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    plens = [8192] if args.smoke else [512, 1024, 2048, 4096, 8192]
    iters = min(args.iters, 2) if args.smoke else args.iters
    args.iters = iters
    chunk = args.chunk

    results = {}
    for cfg in bench_configs():
        rows = []
        for plen in plens:
            row = bench_prefill(cfg, plen, chunk, plen + 64, args.iters)
            rows.append(row)
            mem = (f"{row['mem_ratio']:.3f}" if row["mem_ratio"] is not None
                   else "n/a")
            print(f"{cfg.name:12s} L={plen:6d} oneshot "
                  f"{row['oneshot_ttft_ms']:8.1f} ms | chunked({chunk}) "
                  f"{row['chunked_ttft_ms']:8.1f} ms "
                  f"(x{row['ttft_ratio']:.2f}) | temp mem ratio {mem}")
        results[cfg.name] = rows

    inter = bench_interleave(long_len=8192, chunk=chunk)
    print(f"interleave   L={inter['long_len']} fairness "
          f"{inter['fairness']:.2f} "
          f"({inter['interleave_decode_iters']}/"
          f"{inter['interleave_iters']} chunk-iters with decode), "
          f"{inter['completed']}/{inter['submitted']} done, "
          f"{inter['wall_s']:.1f}s")

    record = {"bench": "prefill", "smoke": bool(args.smoke),
              "schema_version": TRACE_SCHEMA_VERSION,
              "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
              "chunk": chunk, "results": results, "interleave": inter}
    runs = []
    if os.path.exists(OUT_PATH):
        try:
            with open(OUT_PATH) as f:
                runs = json.load(f).get("runs", [])
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump({"bench": "prefill", "runs": runs}, f, indent=2)
    print(f"appended run {len(runs)} to {OUT_PATH}")

    if args.smoke:
        failures = []
        for name, rows in results.items():
            row = rows[-1]                            # the 8K+ point
            if row["oneshot_temp_bytes"] > 0 and not (
                    row["chunked_temp_bytes"] < row["oneshot_temp_bytes"]):
                failures.append(
                    f"{name}: chunked temp {row['chunked_temp_bytes']} >= "
                    f"one-shot {row['oneshot_temp_bytes']} at L={row['plen']}")
            if row["ttft_ratio"] > TTFT_FACTOR:
                failures.append(
                    f"{name}: chunked TTFT x{row['ttft_ratio']:.2f} over "
                    f"one-shot exceeds the {TTFT_FACTOR}x bound")
        if inter["completed"] != inter["submitted"]:
            failures.append("interleave workload did not complete")
        if inter["fairness"] < 1.0:
            failures.append(
                f"head-of-line blocking: fairness {inter['fairness']:.2f} "
                f"< 1.0 ({inter['interleave_decode_iters']}/"
                f"{inter['interleave_iters']})")
        if failures:
            raise SystemExit("prefill smoke FAILED:\n  " +
                             "\n  ".join(failures))
        print("smoke OK: flat chunked memory, TTFT within bound, "
              "fairness 1.0")


if __name__ == "__main__":
    main()
