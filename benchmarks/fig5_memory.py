"""Paper Fig. 5: memory footprint & OOM frontier on consumer (24 GB) and
edge (8 GB) devices.  Claims: Transformers OOM ~57-65K, Zamba2 ~49K,
Falcon-H1 ~164K, Mamba/Mamba2 >=220K (4x); SSM footprint ~64% smaller."""
from __future__ import annotations

from repro.core.config import JETSON_ORIN_NANO, RTX_4090
from repro.core.memmodel import inference_memory, max_seq_len
from repro.core.registry import get
from benchmarks.common import Emitter

MODELS = [
    ("phi-3-mini", dict(eager_attention=True), 6144),      # paper: 4-8K
    ("qwen2.5-0.5b", {}, 57344),
    ("llama3.2-1b", {}, 65536),
    ("zamba2-1.2b", {}, 49152),
    ("falcon-h1-0.5b", {}, 163840),
    ("mamba2-780m", {}, 220000),
    ("mamba-130m", {}, 220000),
]


def run(em: Emitter) -> None:
    for name, kw, paper_val in MODELS:
        cfg = get(name)
        m24 = max_seq_len(cfg, RTX_4090.hbm_bytes, **kw)
        m8 = max_seq_len(cfg, JETSON_ORIN_NANO.hbm_bytes, **kw)
        dev = m24 / paper_val if paper_val else 0
        em.emit(f"fig5.oom24gb.{name}", m24,
                f"paper~{paper_val}_ratio={dev:.2f}")
        em.emit(f"fig5.oom8gb.{name}", m8, "")
    # memory breakdown at 57K (the 64%-reduction claim)
    q = inference_memory(get("qwen2.5-0.5b"), 1, 57344)
    m = inference_memory(get("mamba2-780m"), 1, 57344)
    em.emit("fig5.mem57k.qwen2.5-0.5b", q.total / 1e6,
            f"kv={q.kv_cache / 1e9:.2f}GB_act={q.activations / 1e9:.2f}GB")
    em.emit("fig5.mem57k.mamba2-780m", m.total / 1e6,
            f"state={m.ssm_state / 1e6:.1f}MB")
    em.emit("fig5.claim.ssm_mem_reduction", (1 - m.total / q.total) * 100,
            "paper~64%_at_oom_comparable_points")
    # 4x frontier claim
    tf = max_seq_len(get("qwen2.5-0.5b"), RTX_4090.hbm_bytes)
    ssm_tested = 220000   # paper's max tested length (no OOM observed)
    em.emit("fig5.claim.ssm_4x_frontier", ssm_tested / tf * 100,
            f"ratio={ssm_tested / tf:.1f}x_paper~4x")
