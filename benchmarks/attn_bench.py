"""Attention-scaling benchmark: per-chunk/decode attention cost must track
the LIVE PREFIX, not ``max_seq`` — the attention analog of the decode and
prefill trajectories.

The paper's operator breakdown shows attention over the KV window dominating
Transformer/hybrid latency as context grows.  Before KV bucketing, every
chunked-prefill step attended the entire ``max_seq`` cache under a mask, so
a chunk at offset 1K cost the same as one at offset ``max_seq`` — a flat
line where the paper measures a scaling curve.  This bench drives the same
compiled chunk program at several prefix offsets, once with the static KV
bucket the serving layer would pick and once against the full cache,
reporting per-chunk wall time:

  * bucketed time must GROW with the offset (monotone-in-prefix), and
  * the early-prefix bucketed chunk must beat the full-cache chunk.

Two correctness sections ride along (the tentpole's parity criteria):
flash-decode kernel ref/interpret parity across dense-GQA / hybrid-MHA
shapes and split-K values, and chunked-prefill (buckets on) parity with
one-shot prefill including a bit-exact greedy continuation.

Results append to ``BENCH_attn.json``; ``--smoke`` is the reduced sweep
wired into ``scripts/verify.sh`` with the assertions above as the gate.

  PYTHONPATH=src python benchmarks/attn_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.kernels.attn_decode.kernel import decode_attention_pallas
from repro.kernels.attn_decode.ref import decode_attention_ref
from repro.models.lm import (decode_tokens, init_lm_cache, init_lm_params,
                             lm_prefill)
from repro.serving.bucketing import select_kv_bucket
from repro.serving.prefill import _jitted_chunk_step, chunked_prefill
from repro.serving.telemetry import TRACE_SCHEMA_VERSION, operator_costs

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_attn.json")
# the early-prefix chunk must beat the late one by at least this factor
# (theoretical gap is ~4x bucket rows; the margin absorbs CPU timer noise)
MONOTONE_MARGIN = 1.15


def _dense_cfg(d_model: int = 64):
    return ModelConfig(
        name="transformer", family="dense", n_layers=2, d_model=d_model,
        d_ff=2 * d_model, vocab_size=256,
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=d_model // 4,
                        dense_cutoff=1024),
        layer_pattern=("dense",), vocab_pad_multiple=16)


def _hybrid_cfg(d_model: int = 64):
    return ModelConfig(
        name="hybrid", family="hybrid", n_layers=4, d_model=d_model,
        d_ff=0, vocab_size=256,
        ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
        layer_pattern=("mamba2", "mamba2+shared"),
        shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                               head_dim=d_model // 4, dense_cutoff=1024),
        shared_attn_d_ff=2 * d_model, vocab_pad_multiple=16)


# ---------------------------------------------------- chunk-attention scaling
def bench_chunk_scaling(cfg, max_seq: int, chunk: int, offsets, iters: int):
    """Time ONE compiled prefill-chunk step at several prefix offsets, with
    the serving layer's KV bucket vs the full cache."""
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    template = init_lm_cache(cfg, 1, max_seq)
    step = _jitted_chunk_step(cfg, None)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, chunk), 0,
                              cfg.vocab_size, jnp.int32)
    lens = jnp.full((1,), chunk, jnp.int32)
    rows = []
    for off in offsets:
        cache = dict(template, pos=jnp.full((1,), off, jnp.int32))
        bucket = select_kv_bucket(min(off + chunk, max_seq), max_seq)

        def timed(kv_bucket):
            lg, _ = step(params, toks, lens, cache, kv_bucket=kv_bucket)
            jax.block_until_ready(lg)

        timed(bucket), timed(None)                         # compile+warm
        best_b = best_f = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            timed(bucket)
            best_b = min(best_b, time.perf_counter() - t0)
            t0 = time.perf_counter()
            timed(None)
            best_f = min(best_f, time.perf_counter() - t0)
        rows.append({"offset": off, "bucket": bucket,
                     "bucketed_ms": 1e3 * best_b, "full_ms": 1e3 * best_f,
                     "speedup_vs_full": best_f / best_b})
        print(f"{cfg.name:12s} off={off:6d} bucket={bucket:6d} "
              f"bucketed {1e3 * best_b:7.2f} ms | full(max_seq={max_seq}) "
              f"{1e3 * best_f:7.2f} ms | x{best_f / best_b:.2f}")
    # static operator attribution of the chunk program at the deepest
    # offset's rung — the regime where the paper's attention-vs-ssm
    # operator split is most visible
    cache = dict(template, pos=jnp.full((1,), offsets[-1], jnp.int32))
    lowered = step.lower(params, toks, lens, cache, kv_bucket=rows[-1]["bucket"])
    shares = operator_costs(lowered.compile())
    print(f"{cfg.name:12s} chunk program @bucket={rows[-1]['bucket']}: "
          + ", ".join(f"{k}={v['flop_share']:.2f}"
                      for k, v in shares["by_class"].items()))
    return rows, shares


# ------------------------------------------------------- flash-decode parity
def bench_decode_parity() -> dict:
    """ref vs Pallas-interpret parity of the split-K flash-decode kernel on
    dense-GQA and hybrid-MHA (shared-attention) shapes."""
    shapes = {
        "dense_gqa": (2, 8, 2, 512, 16),      # h=8 over 2 kv heads (GQA)
        "hybrid_mha": (2, 4, 4, 512, 16),     # shared block: kvh == h
    }
    out = {}
    rng = np.random.default_rng(0)
    for name, (b, h, kvh, s, d) in shapes.items():
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (b, h, d))
        k = jax.random.normal(ks[1], (b, kvh, s, d))
        v = jax.random.normal(ks[2], (b, kvh, s, d))
        vl = jnp.asarray(rng.integers(1, s, b), jnp.int32)
        o_ref = decode_attention_ref(q, k, v, valid_len=vl)
        worst = 0.0
        for sk in (1, 2, 4, None):
            o_k = decode_attention_pallas(q, k, v, valid_len=vl, block_s=128,
                                          split_k=sk, interpret=True)
            worst = max(worst, float(jnp.abs(o_k - o_ref).max()))
        out[name] = worst
        print(f"decode-parity {name:11s} max_err={worst:.2e} "
              f"(split_k 1/2/4/auto)")
    return out


# ------------------------------------------------------ chunk-prefill parity
def bench_chunk_parity() -> dict:
    """Bucketed chunked prefill vs one-shot: logits tolerance + bit-exact
    8-token greedy continuation, dense and hybrid."""
    out = {}
    for cfg in (_dense_cfg(), _hybrid_cfg()):
        params = init_lm_params(cfg, jax.random.PRNGKey(0))
        B, L, MS = 2, 48, 512
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                                  cfg.vocab_size, jnp.int32)
        ref_logits, ref_cache = lm_prefill(cfg, params, {"tokens": toks},
                                           init_lm_cache(cfg, B, MS))
        logits, cache = chunked_prefill(cfg, params, toks,
                                        init_lm_cache(cfg, B, MS),
                                        chunk_size=16)
        err = float(jnp.abs(logits.astype(jnp.float32)
                            - ref_logits.astype(jnp.float32)).max())
        first = jnp.argmax(ref_logits[..., :cfg.vocab_size],
                           -1).astype(jnp.int32)
        t_ref, _ = decode_tokens(cfg, params, ref_cache, first, 8)
        t_chk, _ = decode_tokens(cfg, params, cache, first, 8)
        exact = bool((np.asarray(t_ref) == np.asarray(t_chk)).all())
        out[cfg.name] = {"logits_err": err, "continuation_exact": exact}
        print(f"chunk-parity {cfg.name:12s} logits_err={err:.2e} "
              f"continuation_exact={exact}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + monotone/parity assertions")
    ap.add_argument("--max-seq", type=int, default=8192)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    max_seq, chunk = args.max_seq, args.chunk
    cand = ([1024, max_seq - chunk] if args.smoke
            else [512, 1024, 2048, 4096, max_seq - chunk])
    # clamp to offsets whose chunk still fits the cache, ascending; small
    # --max-seq values collapse the sweep rather than inverting it
    offsets = sorted({max(0, min(o, max_seq - chunk)) for o in cand})
    iters = min(args.iters, 2) if args.smoke else args.iters

    scaling = {}
    op_shares = {}
    for cfg in (_dense_cfg(), _hybrid_cfg()):
        rows, shares = bench_chunk_scaling(cfg, max_seq, chunk,
                                           offsets, iters)
        scaling[cfg.name] = rows
        op_shares[cfg.name] = shares
    parity = bench_decode_parity()
    chunk_par = bench_chunk_parity()

    # compact per-bucket latency view of the scaling rows (rung -> ms)
    per_bucket = {name: {str(r["bucket"]): r["bucketed_ms"] for r in rows}
                  for name, rows in scaling.items()}
    record = {"bench": "attn", "smoke": bool(args.smoke),
              "schema_version": TRACE_SCHEMA_VERSION,
              "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
              "max_seq": max_seq, "chunk": chunk, "scaling": scaling,
              "per_bucket_ms": per_bucket, "operator_shares": op_shares,
              "decode_parity_err": parity, "chunk_parity": chunk_par}
    runs = []
    if os.path.exists(OUT_PATH):
        try:
            with open(OUT_PATH) as f:
                runs = json.load(f).get("runs", [])
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump({"bench": "attn", "runs": runs}, f, indent=2)
    print(f"appended run {len(runs)} to {OUT_PATH}")

    if args.smoke:
        failures = []
        for name, rows in scaling.items():
            early, late = rows[0], rows[-1]
            if len(rows) < 2:
                failures.append(
                    f"{name}: --max-seq {max_seq} leaves a single offset; "
                    "the monotone-in-prefix gate needs a longer cache")
                continue
            if not (early["bucketed_ms"] * MONOTONE_MARGIN
                    < late["bucketed_ms"]):
                failures.append(
                    f"{name}: chunk attention flat in max_seq — "
                    f"{early['bucketed_ms']:.2f} ms at offset "
                    f"{early['offset']} vs {late['bucketed_ms']:.2f} ms at "
                    f"offset {late['offset']}")
            if not (early["bucketed_ms"] < early["full_ms"]):
                failures.append(
                    f"{name}: bucketing no faster than the full cache at "
                    f"offset {early['offset']} "
                    f"({early['bucketed_ms']:.2f} vs "
                    f"{early['full_ms']:.2f} ms)")
        for name, shares in op_shares.items():
            fam = shares["by_class"]
            total = sum(c["flop_share"] for c in fam.values())
            if "gemm" not in fam or fam["gemm"]["flop_share"] <= 0.0:
                failures.append(
                    f"{name}: chunk program has no gemm attribution "
                    f"({sorted(fam)})")
            if not 0.99 <= total <= 1.01:
                failures.append(
                    f"{name}: operator flop shares sum to {total:.4f}")
        for name, err in parity.items():
            if err > 2e-4:
                failures.append(f"flash-decode parity {name}: err {err:.2e}")
        for name, row in chunk_par.items():
            if row["logits_err"] > 2e-2 or not row["continuation_exact"]:
                failures.append(f"chunk parity {name}: {row}")
        if failures:
            raise SystemExit("attn smoke FAILED:\n  " + "\n  ".join(failures))
        print("smoke OK: chunk attention tracks the live prefix, "
              "flash-decode parity holds, chunked prefill parity holds")


if __name__ == "__main__":
    main()
