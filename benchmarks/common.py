"""Shared benchmark infrastructure.

Per the hardware-adaptation note in DESIGN.md: this container has no GPU
or TPU, so the paper's wall-clock figures are reproduced through the
characterization flow itself — lower+compile the real model at the real
shape (single device), run the HLO cost analyzer, and convert per-kernel
costs to time on the paper's device specs (RTX 4090 / Jetson Orin Nano)
with the eager no-overlap execution model the paper measured under.
Wall-clock *measurements* on CPU are used for reduced configs to verify
the asymptotic claims empirically (bench `fig1_measured`).
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import HardwareSpec, ModelConfig
from repro.core.hlo_analysis import CostSummary, analyze_hlo_text
from repro.core.registry import get
from repro.core.roofline import op_class_times
from repro.models.lm import init_lm_cache, lm_decode_step, lm_forward, \
    lm_prefill

CACHE_DIR = os.path.join(os.path.dirname(__file__), "results", "cache")
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
os.makedirs(CACHE_DIR, exist_ok=True)


def _cache_path(key: str) -> str:
    return os.path.join(CACHE_DIR, key.replace("/", "_") + ".json")


def cost_for(model: str, kind: str, seq: int, batch: int = 1,
             gen_cache: Optional[int] = None) -> Dict:
    """Lower+compile one step of `model` at shape and return per-class
    flops/bytes (cached on disk — compiles are the slow part)."""
    key = f"{model}__{kind}__s{seq}__b{batch}__v2"
    path = _cache_path(key)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg = get(model)
    out = _compute_cost(cfg, kind, seq, batch)
    with open(path, "w") as f:
        json.dump(out, f)
    return out


def _compute_cost(cfg: ModelConfig, kind: str, seq: int, batch: int) -> Dict:
    psds = _param_sds(cfg)
    if kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        csds = jax.eval_shape(functools.partial(
            init_lm_cache, cfg, batch, seq))

        def step(p, i, c):
            return lm_prefill(cfg, p, i, c)

        lowered = jax.jit(step).lower(psds, specs, csds)
    elif kind == "decode":
        tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        csds = jax.eval_shape(functools.partial(
            init_lm_cache, cfg, batch, seq))

        def step(p, t, c):
            return lm_decode_step(cfg, p, t, c)

        lowered = jax.jit(step).lower(psds, tok, csds)
    else:
        raise ValueError(kind)
    compiled = lowered.compile()
    from repro.core.hlo_analysis import HloAnalyzer
    an = HloAnalyzer(compiled.as_text())
    cost = an.summarize()
    fused = an.summarize_fused()

    def klist(c):
        return [{"clazz": k.clazz, "scope": k.scope,
                 "flops": k.flops * k.count, "bytes": k.bytes * k.count}
                for k in c.kernels]

    # "kernels" = deployed fused-kernel path (the paper measured fused CUDA
    # kernels); "kernels_eager" = unfused ref path for comparison.
    return {
        "flops": cost.flops, "bytes": cost.bytes,
        "by_class": cost.by_class(),
        "kernels": klist(fused),
        "kernels_eager": klist(cost),
    }


def _param_sds(cfg: ModelConfig):
    from repro.launch.steps import param_sds
    return param_sds(cfg, dtype=cfg.compute_dtype)


def time_on(cost: Dict, hw: HardwareSpec) -> float:
    """Eager no-overlap time model: Σ_kernel max(compute, memory)."""
    t = 0.0
    for k in cost["kernels"]:
        t += max(k["flops"] / hw.peak_flops, k["bytes"] / hw.hbm_bw)
    return t


def class_times(cost: Dict, hw: HardwareSpec) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for k in cost["kernels"]:
        t = max(k["flops"] / hw.peak_flops, k["bytes"] / hw.hbm_bw)
        out[k["clazz"]] = out.get(k["clazz"], 0.0) + t
    return out


def energy_on(cost: Dict, hw: HardwareSpec) -> float:
    e = 0.0
    for k in cost["kernels"]:
        t = max(k["flops"] / hw.peak_flops, k["bytes"] / hw.hbm_bw)
        util = 0.9 if k["clazz"] == "gemm" else 0.55
        e += t * (hw.idle_w + util * (hw.power_w - hw.idle_w))
    return e


def wall_time(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


class Emitter:
    """Collect `name,us_per_call,derived` rows (the scaffold CSV contract)."""

    def __init__(self):
        self.rows = []

    def emit(self, name: str, us: float, derived: str = "") -> None:
        self.rows.append((name, us, derived))
        print(f"{name},{us:.3f},{derived}")

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in self.rows:
                f.write(f"{n},{u:.3f},{d}\n")
