"""Paper Sec. III-C: quantization effect (Quamba2 W4A8 on Mamba2-780m).

Claims: 3.5x weight reduction (1488 -> 424 MB); 1.26x TTFT and 1.5x TPOT
speedup at 65K prefill on the RTX 4090."""
from __future__ import annotations

from repro.core.config import RTX_4090
from repro.core.memmodel import weight_bytes
from repro.core.registry import get
from benchmarks.common import Emitter, cost_for


def _time_scaled(cost, hw, wbytes_scale: float) -> float:
    """W4A8 roofline: weight-stream bytes shrink ~3.5x; compute on int8
    paths ~2x bf16 throughput for GEMM kernels."""
    t = 0.0
    for k in cost["kernels"]:
        byts = k["bytes"] * (wbytes_scale if k["clazz"] == "gemm" else 1.0)
        flops_rate = hw.peak_flops * (2.0 if k["clazz"] == "gemm" else 1.0)
        t += max(k["flops"] / flops_rate, byts / hw.hbm_bw)
    return t


def run(em: Emitter) -> None:
    cfg = get("mamba2-780m")
    w16 = weight_bytes(cfg, 2)
    w4 = int(cfg.param_count() * 0.57)   # 4-bit + scales/zeros + a few 8-bit
    em.emit("quant.weights.bf16", w16 / 1e6, f"paper=1488MB")
    em.emit("quant.weights.w4a8", w4 / 1e6,
            f"paper=424MB_ratio={w16 / w4:.2f}x_paper=3.5x")
    c = cost_for("mamba2-780m", "prefill", 65536)
    t_bf16 = _time_scaled(c, RTX_4090, 1.0)
    t_w4 = _time_scaled(c, RTX_4090, 0.285)
    em.emit("quant.ttft65k.speedup", t_bf16 / t_w4 * 100,
            f"paper=1.26x_model={t_bf16 / t_w4:.2f}x")
    cd = cost_for("mamba2-780m", "decode", 65536)
    d_bf16 = _time_scaled(cd, RTX_4090, 1.0)
    d_w4 = _time_scaled(cd, RTX_4090, 0.285)
    em.emit("quant.tpot65k.speedup", d_bf16 / d_w4 * 100,
            f"paper=1.5x_model={d_bf16 / d_w4:.2f}x")
