"""Decode-path benchmark: per-token python loop vs the fused on-device loop.

Measures TPOT (time per output token) and tokens/sec for the two decode
drivers on a transformer, an SSM, and a hybrid config:

  * ``loop``  — one jitted ``lm_decode_step`` per token, host argmax and a
                device<->host token round-trip every step (the pre-fusion
                serving path).
  * ``fused`` — ``decode_tokens``: the whole burst inside one ``lax.scan``
                with on-device argmax (one dispatch, zero per-token syncs).

Results append the decode perf trajectory to ``BENCH_decode.json`` at the
repo root.  ``--smoke`` runs the reduced sweep used by ``scripts/verify.sh``
and asserts the fused loop is >= 2x the per-token loop.

  PYTHONPATH=src python benchmarks/decode_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_cache, init_lm_params
from repro.serving.engine import (make_decode_step, make_decode_tokens,
                                  make_prefill_step)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_decode.json")


def bench_configs(d_model: int = 64):
    attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=d_model // 4)
    return [
        ModelConfig(name="transformer", family="dense", n_layers=4,
                    d_model=d_model, d_ff=2 * d_model, vocab_size=256,
                    attn=attn, layer_pattern=("dense",),
                    vocab_pad_multiple=16),
        ModelConfig(name="ssm", family="ssm", n_layers=4, d_model=d_model,
                    d_ff=0, vocab_size=256,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
                    layer_pattern=("mamba2",), vocab_pad_multiple=16),
        ModelConfig(name="hybrid", family="hybrid", n_layers=4,
                    d_model=d_model, d_ff=0, vocab_size=256,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
                    layer_pattern=("mamba2", "mamba2+shared"),
                    shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                           head_dim=d_model // 4),
                    shared_attn_d_ff=2 * d_model, vocab_pad_multiple=16),
    ]


def _prefilled(cfg, batch: int, plen: int, max_seq: int):
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, plen), 0,
                                cfg.vocab_size, jnp.int32)
    cache = init_lm_cache(cfg, batch, max_seq)
    prefill = jax.jit(make_prefill_step(cfg))
    logits, cache = prefill(params, {"tokens": prompt}, cache)
    first = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    return params, cache, first


def time_decoders(cfg, params, cache, first, gen_len: int,
                  iters: int) -> Tuple[float, float]:
    """Time (loop, fused) interleaved, best-of-iters each: alternating the
    two drivers keeps a shared-machine throttle window from landing on only
    one side of the ratio."""
    step = jax.jit(make_decode_step(cfg))
    decode_n = jax.jit(make_decode_tokens(cfg), static_argnames=("n",))

    def run_loop():
        # the pre-fusion driver: python loop, host round-trip per token
        # exactly as the old greedy/engine loop did
        c, tok = cache, first
        for _ in range(gen_len):
            logits, c = step(params, tok, c)
            nxt = np.asarray(jnp.argmax(logits[:, 0, :cfg.vocab_size], -1),
                             np.int32)
            tok = jnp.asarray(nxt[:, None])
        jax.block_until_ready(tok)

    def run_fused():
        toks, _ = decode_n(params, cache, first, n=gen_len)
        jax.block_until_ready(toks)

    run_loop(), run_fused()                 # warmup / compile
    best_loop = best_fused = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run_loop()
        best_loop = min(best_loop, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_fused()
        best_fused = min(best_fused, time.perf_counter() - t0)
    return best_loop, best_fused


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + >=2x assertion (CI perf gate)")
    ap.add_argument("--gen-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=0,
                    help="0 = default (1 for --smoke: the paper's "
                         "single-stream edge TPOT setting, else 2)")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    gen_len = 64 if args.smoke else args.gen_len
    batch = args.batch or (1 if args.smoke else 2)
    iters = max(args.iters, 5) if args.smoke else args.iters

    results = {}
    for cfg in bench_configs():
        params, cache, first = _prefilled(cfg, batch, 16, 16 + gen_len + 8)
        t_loop, t_fused = time_decoders(cfg, params, cache, first,
                                        gen_len, iters)
        toks = batch * gen_len
        row = {
            "gen_len": gen_len,
            "batch": batch,
            "loop_tpot_ms": 1e3 * t_loop / gen_len,
            "fused_tpot_ms": 1e3 * t_fused / gen_len,
            "loop_tok_s": toks / t_loop,
            "fused_tok_s": toks / t_fused,
            "speedup": t_loop / t_fused,
        }
        results[cfg.name] = row
        print(f"{cfg.name:12s} loop {row['loop_tpot_ms']:7.2f} ms/tok "
              f"({row['loop_tok_s']:8.1f} tok/s) | fused "
              f"{row['fused_tpot_ms']:7.2f} ms/tok "
              f"({row['fused_tok_s']:8.1f} tok/s) | "
              f"speedup {row['speedup']:.2f}x")

    record = {"bench": "decode", "smoke": bool(args.smoke),
              "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
              "results": results}
    runs = []
    if os.path.exists(OUT_PATH):
        try:
            with open(OUT_PATH) as f:
                runs = json.load(f).get("runs", [])
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump({"bench": "decode", "runs": runs}, f, indent=2)
    print(f"appended run {len(runs)} to {OUT_PATH}")

    if args.smoke:
        speedups = [r["speedup"] for r in results.values()]
        gmean = float(np.exp(np.mean(np.log(speedups))))
        worst = min(speedups)
        # gate on the gmean only: per-config wall-clock on a shared host is
        # too noisy for a hard per-config floor (min is still reported)
        if gmean < 2.0:
            raise SystemExit(
                f"fused decode gmean only {gmean:.2f}x over the per-token "
                f"loop (expected >= 2x; min {worst:.2f}x)")
        print(f"smoke OK: gmean speedup {gmean:.2f}x (min {worst:.2f}x)")


if __name__ == "__main__":
    main()
