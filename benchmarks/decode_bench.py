"""Decode-path benchmark: per-token python loop vs the fused on-device loop.

Measures TPOT (time per output token) and tokens/sec for the two decode
drivers on a transformer, an SSM, and a hybrid config:

  * ``loop``  — one jitted ``lm_decode_step`` per token, host argmax and a
                device<->host token round-trip every step (the pre-fusion
                serving path).
  * ``fused`` — ``decode_tokens``: the whole burst inside one ``lax.scan``
                with on-device argmax (one dispatch, zero per-token syncs).

Results append the decode perf trajectory to ``BENCH_decode.json`` at the
repo root.  ``--smoke`` runs the reduced sweep used by ``scripts/verify.sh``
and asserts the fused loop is >= 2x the per-token loop.

``--faults`` benches the fault-tolerance layer instead: the healthy-path
cost of divergence sentinels + periodic checkpointing (engine with
``sentinel=True, checkpoint_every=8`` vs both off, best-of-iters,
asserted < 5% overhead) and one deterministic NaN-recovery run
(checkpoint replay must reproduce the healthy outputs bit-for-bit).

  PYTHONPATH=src python benchmarks/decode_bench.py [--smoke | --faults]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_cache, init_lm_params
from repro.serving.engine import (make_decode_step, make_decode_tokens,
                                  make_prefill_step)
from repro.serving.profiler import PROFILE_SCHEMA_VERSION, Profiler
from repro.serving.telemetry import TRACE_SCHEMA_VERSION

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(ROOT, "BENCH_decode.json")

#: contexts the measured-share sweep decodes at (the longest is where the
#: ssm-family plurality gate applies)
PROFILE_CONTEXTS = (64, 448, 960)


def bench_configs(d_model: int = 64):
    attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=d_model // 4)
    return [
        ModelConfig(name="transformer", family="dense", n_layers=4,
                    d_model=d_model, d_ff=2 * d_model, vocab_size=256,
                    attn=attn, layer_pattern=("dense",),
                    vocab_pad_multiple=16),
        ModelConfig(name="ssm", family="ssm", n_layers=4, d_model=d_model,
                    d_ff=0, vocab_size=256,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
                    layer_pattern=("mamba2",), vocab_pad_multiple=16),
        ModelConfig(name="hybrid", family="hybrid", n_layers=4,
                    d_model=d_model, d_ff=0, vocab_size=256,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
                    layer_pattern=("mamba2", "mamba2+shared"),
                    shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                           head_dim=d_model // 4),
                    shared_attn_d_ff=2 * d_model, vocab_pad_multiple=16),
    ]


def profile_configs(d_model: int = 96):
    """Configs for the *measured* kernel-family share sweep.  Sized so the
    decode burst is honestly SSM-bound (batch 8, d_state 256, headdim 32):
    at toy batch-1 scale the recurrence is weight-read-bound and gemm
    dominates, which says nothing about the paper's regime.  The hybrid
    interleaves one shared-attention layer per six, so the ssm family
    keeps the plurality at the longest smoke context while the attention
    share still grows with context (the paper's crossover trend)."""
    ssm = SSMConfig(d_state=256, headdim=32, chunk=32)
    return [
        ModelConfig(name="ssm-prof", family="ssm", n_layers=4,
                    d_model=d_model, d_ff=0, vocab_size=256, ssm=ssm,
                    layer_pattern=("mamba2",), vocab_pad_multiple=16),
        ModelConfig(name="hybrid-prof", family="hybrid", n_layers=6,
                    d_model=d_model, d_ff=0, vocab_size=256, ssm=ssm,
                    layer_pattern=("mamba2", "mamba2", "mamba2", "mamba2",
                                   "mamba2", "mamba2+shared"),
                    shared_attn=AttnConfig(n_heads=3, n_kv_heads=1,
                                           head_dim=32),
                    shared_attn_d_ff=2 * d_model, vocab_pad_multiple=16),
    ]


def bench_measured_shares(contexts=PROFILE_CONTEXTS, burst: int = 16,
                          reps: int = 3) -> list:
    """Measured per-kernel-family runtime shares vs context length — the
    profiler-trace counterpart of the static ``operator_shares`` record.

    For one SSM and one hybrid config, prefill ``batch=8`` prompts to
    each context length, then wrap ``reps`` steady decode bursts in a
    :class:`Profiler` trace window (compile happens OUTSIDE the window)
    and attribute the device events to families.  On hosts without trace
    support the window degrades to static-weight apportioning and the
    row is flagged ``degraded`` — fig7/fig8 still get a curve, but the
    smoke gate reports it."""
    records = []
    for cfg in profile_configs():
        prof = Profiler(mode="trace")
        rows = []
        for ctx in contexts:
            params, cache, first = _prefilled(cfg, 8, ctx, ctx + burst + 8)
            decode_n = jax.jit(make_decode_tokens(cfg),
                               static_argnames=("n",))
            toks, _ = decode_n(params, cache, first, n=burst)  # compile
            jax.block_until_ready(toks)
            key = f"{cfg.name}@{ctx}"
            prof.register(
                key, decode_n.lower(params, cache, first, n=burst).compile())
            with prof.window(key) as ft:
                for _ in range(reps):
                    toks, _ = decode_n(params, cache, first, n=burst)
                    jax.block_until_ready(toks)
            shares = ft.shares()
            top = max(shares, key=shares.get) if shares else None
            rows.append({"context": ctx, "shares": shares,
                         "plurality": top, "wall_ms": ft.wall_ms,
                         "events": ft.events, "degraded": ft.degraded})
            print(f"measured {cfg.name:12s} ctx={ctx:5d} "
                  f"events={ft.events:6d} top={top} "
                  + " ".join(f"{k}={v:.3f}" for k, v in sorted(
                      shares.items(), key=lambda kv: -kv[1])[:4]))
        records.append({"version": PROFILE_SCHEMA_VERSION, "arch": cfg.name,
                        "family": cfg.family, "mode": prof.mode, "batch": 8,
                        "burst": burst, "reps": reps, "rows": rows})
    return records


def _gate_measured_shares(records: list) -> None:
    """Smoke gates on the measured sweep: both archs present, each row's
    family shares sum to 1 (within float eps), and the ssm family holds
    the plurality at the LONGEST context for the SSM and hybrid configs —
    the paper's measured headline (custom SSM kernels dominate edge
    inference latency)."""
    fams = {r["family"] for r in records}
    if not {"ssm", "hybrid"} <= fams:
        raise SystemExit(f"measured sweep missing an arch: got {fams}, "
                         "need ssm + hybrid")
    for rec in records:
        for row in rec["rows"]:
            total = sum(row["shares"].values())
            if row["shares"] and not 0.999 <= total <= 1.001:
                raise SystemExit(
                    f"{rec['arch']} ctx={row['context']}: measured family "
                    f"shares sum to {total:.4f}")
        last = rec["rows"][-1]
        if last["degraded"]:
            print(f"measured {rec['arch']}: host produced no device trace "
                  "(degraded to static apportioning); plurality gate "
                  "skipped")
            continue
        if last["plurality"] != "ssm":
            raise SystemExit(
                f"{rec['arch']} ctx={last['context']}: expected the ssm "
                f"family plurality in measured shares, got "
                f"{last['plurality']} ({last['shares']})")
    print("measured-share smoke OK: ssm-family plurality at ctx="
          f"{records[0]['rows'][-1]['context']} for "
          + ", ".join(f"{r['arch']}="
                      f"{r['rows'][-1]['shares'].get('ssm', 0):.3f}"
                      for r in records))


def _prefilled(cfg, batch: int, plen: int, max_seq: int):
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, plen), 0,
                                cfg.vocab_size, jnp.int32)
    cache = init_lm_cache(cfg, batch, max_seq)
    prefill = jax.jit(make_prefill_step(cfg))
    logits, cache = prefill(params, {"tokens": prompt}, cache)
    first = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    return params, cache, first


def time_decoders(cfg, params, cache, first, gen_len: int,
                  iters: int) -> Tuple[float, float]:
    """Time (loop, fused) interleaved, best-of-iters each: alternating the
    two drivers keeps a shared-machine throttle window from landing on only
    one side of the ratio."""
    step = jax.jit(make_decode_step(cfg))
    decode_n = jax.jit(make_decode_tokens(cfg), static_argnames=("n",))

    def run_loop():
        # the pre-fusion driver: python loop, host round-trip per token
        # exactly as the old greedy/engine loop did
        c, tok = cache, first
        for _ in range(gen_len):
            logits, c = step(params, tok, c)
            nxt = np.asarray(jnp.argmax(logits[:, 0, :cfg.vocab_size], -1),
                             np.int32)
            tok = jnp.asarray(nxt[:, None])
        jax.block_until_ready(tok)

    def run_fused():
        toks, _ = decode_n(params, cache, first, n=gen_len)
        jax.block_until_ready(toks)

    run_loop(), run_fused()                 # warmup / compile
    best_loop = best_fused = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run_loop()
        best_loop = min(best_loop, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_fused()
        best_fused = min(best_fused, time.perf_counter() - t0)
    return best_loop, best_fused


def _append_run(record: dict) -> None:
    runs = []
    if os.path.exists(OUT_PATH):
        try:
            with open(OUT_PATH) as f:
                runs = json.load(f).get("runs", [])
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump({"bench": "decode", "runs": runs}, f, indent=2)
    print(f"appended run {len(runs)} to {OUT_PATH}")


def bench_faults(gen_len: int, iters: int) -> dict:
    """Healthy-path overhead of the fault-tolerance layer + a recovery
    demo, measured in two decoupled parts:

    1. **Sentinel program cost** — the XLA cost model's flop/byte counts
       for the compiled decode burst with and without ``with_sentinel``.
       Wall-clocking two *different* XLA programs against each other on
       this host is dominated by a per-compilation code-layout lottery
       (identical math measured up to +-12% apart), so the program-level
       delta is gated analytically: the sentinel adds one ``isfinite``
       reduce per step, < 1% of either count, deterministically.
    2. **Checkpoint host cost** — the engine's own ``stats["ckpt_ms"]``
       (time inside the periodic-offload path: full-cache transfer, slot
       slicing, crc) as a fraction of the ft engine's wall time, gated at
       < 5%.  At this bench's toy scale (0.4 MB cache) the *indirect*
       cost — each tick's memcpy evicting the decode working set from L2
       — rivals the direct cost and swings with per-process core/cache
       placement, so end-to-end wall ratios against a baseline engine
       are recorded informationally (same shared jitted decode callable
       on both sides, best-of-N, GC fenced, alternating order) but the
       gate is the direct fraction, which is what survives at real cache
       sizes where burst compute dwarfs a slot memcpy."""
    from repro.core.hlo_analysis import xla_cost_dict
    from repro.serving.bucketing import select_kv_bucket
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.fault_inject import FaultPlan

    cfg = bench_configs()[2]                    # hybrid: both layer kinds
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (24, 17)]

    def build(sentinel, ckpt, plan=None):
        return ServingEngine(cfg, params, slots=2, max_seq=128 + gen_len,
                             decode_block=8, chunk_size=32,
                             sentinel=sentinel, checkpoint_every=ckpt,
                             fault_plan=plan)

    def run_once(eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=gen_len))
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        eng.run(max_iters=10_000)
        dt = time.perf_counter() - t0
        gc.enable()
        done = {r.rid: list(r.out) for r in eng.finished[-len(prompts):]}
        assert all(r.status == "ok" for r in eng.finished), \
            [r.status for r in eng.finished]
        return dt, done

    ft = build(sentinel=True, ckpt=8)
    base = build(sentinel=True, ckpt=0)
    base._decode_n = ft._decode_n   # same jitted callable: no XLA lottery
    run_once(base), run_once(ft)                # warmup / compile

    # part 1: sentinel program cost via the XLA cost model (deterministic)
    bucket = (select_kv_bucket(ft.kv_extent, ft.kv_extent)
              if ft.kv_buckets else None)
    deltas = {}
    costs = {}
    for ws in (False, True):
        lowered = ft._decode_n.lower(
            ft.params, ft.cache, jnp.asarray(ft.tokens), n=ft.decode_block,
            kv_bucket=bucket, rope_len=ft.rope_len, with_sentinel=ws)
        costs[ws] = xla_cost_dict(lowered.compile())
    for key in ("flops", "bytes accessed"):
        a, b = costs[False].get(key, 0.0), costs[True].get(key, 0.0)
        if a > 0:
            deltas[key] = b / a - 1.0
    sentinel_delta = max(deltas.values(), default=0.0)

    # part 2: checkpoint host cost, identical compiled programs both sides
    best_base = best_ft = float("inf")
    fracs = []
    for i in range(iters):
        ck0 = ft.stats["ckpt_ms"]
        if i % 2 == 0:
            t_base = run_once(base)[0]
            t_ft, healthy_out = run_once(ft)
        else:
            t_ft, healthy_out = run_once(ft)
            t_base = run_once(base)[0]
        best_base = min(best_base, t_base)
        best_ft = min(best_ft, t_ft)
        fracs.append((ft.stats["ckpt_ms"] - ck0) / (t_ft * 1e3))
    overhead = float(np.median(fracs))
    e2e = best_ft / best_base - 1.0

    # deterministic recovery: NaN poisons slot 0 mid-decode; checkpoint
    # replay must end in status=ok with the healthy run's exact tokens
    rec = build(sentinel=True, ckpt=4,
                plan=FaultPlan.from_spec("nan_decode@iter=4:slot=0"))
    t_rec, rec_out = run_once(rec)
    assert rec.stats["divergences"] == 1 and rec.stats["replays"] == 1, \
        rec.stats
    assert rec_out == healthy_out, "recovered output diverged from healthy"

    toks = len(prompts) * gen_len
    row = {
        "gen_len": gen_len, "requests": len(prompts),
        "base_tok_s": toks / best_base,
        "ft_tok_s": toks / best_ft,
        "ckpt_overhead": overhead,
        "e2e_overhead": e2e,
        "sentinel_program_delta": sentinel_delta,
        "recovery_run_s": t_rec,
        "recovered_bit_identical": True,
    }
    print(f"faults: base {row['base_tok_s']:8.1f} tok/s | "
          f"ft {row['ft_tok_s']:8.1f} tok/s | checkpoint overhead "
          f"{100 * overhead:+.2f}% direct ({100 * e2e:+.2f}% e2e at toy "
          f"scale) | sentinel program delta {100 * sentinel_delta:+.3f}% "
          f"| recovery replayed bit-identically in {t_rec:.2f}s")
    if sentinel_delta >= 0.01:
        raise SystemExit(
            f"sentinel program flop/byte delta {100 * sentinel_delta:.2f}% "
            "(budget < 1%)")
    if overhead >= 0.05:
        raise SystemExit(
            f"checkpoint overhead {100 * overhead:.2f}% on the healthy "
            "path (budget < 5%)")
    print(f"faults smoke OK: checkpoint overhead {100 * overhead:+.2f}% "
          f"(< 5%), sentinel program delta {100 * sentinel_delta:+.3f}% "
          "(< 1%)")
    return row


def bench_restart(ctx: int = 1024, gen_len: int = 128) -> dict:
    """Engine-restart recovery cost vs redo-from-scratch at the longest
    smoke context.  Protocol: one long-prompt request is killed
    (``SimulatedCrash``, deterministic ``kill`` clause) mid-decode near
    the end of its stream; a fresh engine over the same durable
    :class:`CheckpointStore` rehydrates from the last committed
    checkpoint blob and finishes the stream.  Gates: the recovered
    tokens are bit-identical to an uninterrupted run, and recovery wall
    time (construction/rehydration + remaining decode) stays < 20% of
    redoing the whole prefill+decode — the whole point of durable
    checkpoints is that a crash does NOT re-pay the O(ctx) prefix, which
    at the paper's 57K-token contexts is minutes of work.  All engines
    share one jitted decode callable (and the globally cached prefill
    step), so the ratio measures recomputation, not the compile
    lottery."""
    import shutil
    import tempfile

    from repro.serving.engine import Request, ServingEngine
    from repro.serving.fault_inject import FaultPlan, SimulatedCrash
    from repro.serving.store import CheckpointStore

    cfg = bench_configs()[2]                    # hybrid: both layer kinds
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, ctx).astype(np.int32)
    chunk = 128
    kw = dict(slots=1, max_seq=ctx + gen_len + 8, decode_block=8,
              chunk_size=chunk, checkpoint_every=2)
    prefill_iters = -(-ctx // chunk)
    decode_iters = -(-gen_len // kw["decode_block"])
    # kill at the LAST decode burst, placed one iteration after a
    # committed checkpoint (parity nudge below keeps that true for any
    # --ctx): recovery replays the minimum honest amount — one full
    # burst plus the killed one — while redo re-pays the whole stream
    last_burst = prefill_iters + decode_iters - 2
    if last_burst % kw["checkpoint_every"] != 1:
        decode_iters += 1
        gen_len = decode_iters * kw["decode_block"]
        last_burst += 1
    kill_iter = last_burst

    shared = {}

    def build(store=None, plan=None):
        eng = ServingEngine(cfg, params, fault_plan=plan, store=store, **kw)
        eng._decode_n = shared.setdefault("decode_n", eng._decode_n)
        return eng

    def run_timed(eng):
        eng.submit(Request(rid=0, prompt=prompt, max_new=gen_len))
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        eng.run(max_iters=10_000)
        dt = time.perf_counter() - t0
        gc.enable()
        (req,) = eng.finished
        assert req.status == "ok", (req.status, str(req.error))
        return dt, list(req.out)

    def crash_then_recover():
        """One full kill/restart cycle; returns (recovery wall s,
        recovered engine)."""
        store_dir = tempfile.mkdtemp(prefix="repro-restart-")
        try:
            crashed = build(store=CheckpointStore(store_dir),
                            plan=FaultPlan.from_spec(
                                f"kill@iter={kill_iter}"))
            crashed.submit(Request(rid=0, prompt=prompt, max_new=gen_len))
            try:
                crashed.run(max_iters=10_000)
                raise SystemExit(
                    f"restart bench: kill@iter={kill_iter} never fired "
                    f"({crashed.stats['iters']} iterations ran)")
            except SimulatedCrash:
                pass
            gc.collect()
            gc.disable()
            t0 = time.perf_counter()
            eng2 = build(store=CheckpointStore(store_dir))  # rehydrates
            eng2.run(max_iters=10_000)
            dt = time.perf_counter() - t0
            gc.enable()
            return dt, eng2
        finally:
            shutil.rmtree(store_dir, ignore_errors=True)

    _, ref_out = run_timed(build())             # warm: compiles both paths
    redo_s, out2 = run_timed(build())           # redo-from-scratch, warm
    assert out2 == ref_out
    # warm the restore path too: a long-lived engine keeps these programs
    # hot (slot restore is the same path preemption uses every day) — the
    # 20% gate measures recomputation avoided, not first-ever dispatches
    crash_then_recover()
    recover_s, eng2 = crash_then_recover()
    if eng2.recovery.get("restored") != 1:
        raise SystemExit(
            "restart bench: expected exactly one blob-restored request, "
            f"got rehydration {eng2.recovery} — the < 20% gate is only "
            "meaningful against a mid-stream recovery")
    (req,) = eng2.finished
    bit_identical = req.status == "ok" and list(req.out) == ref_out
    ratio = recover_s / redo_s
    row = {
        "context": ctx, "gen_len": gen_len, "kill_iter": kill_iter,
        "redo_s": redo_s, "recover_s": recover_s,
        "recover_ratio": ratio, "bit_identical": bit_identical,
        "recovery": dict(eng2.recovery),
    }
    print(f"restart: ctx {ctx} | redo {redo_s * 1e3:7.1f}ms | recover "
          f"{recover_s * 1e3:7.1f}ms ({100 * ratio:.1f}% of redo) | "
          f"rehydration {eng2.recovery} | bit-identical: {bit_identical}")
    if not bit_identical:
        raise SystemExit(
            "restart bench: recovered stream is not bit-identical "
            f"(status {req.status}, error {req.error})")
    if ratio >= 0.20:
        raise SystemExit(
            f"restart bench: recovery took {100 * ratio:.1f}% of "
            "redo-from-scratch (budget < 20%)")
    print(f"restart smoke OK: recovery {100 * ratio:.1f}% of redo (< 20%), "
          "stream bit-identical across the crash")
    return row


def bench_serving_telemetry(gen_len: int) -> dict:
    """Per-(phase, KV-bucket) latency records plus static operator-level
    cost attribution for the compiled decode burst — the paper's operator
    breakdown (selective-scan share vs gemm share) attached to every
    decode record so the longitudinal trajectory carries *where* the time
    went, not just how much.  Runs a short serving window on the hybrid
    config sized so decode climbs at least one bucket rung, then reads
    the engine's telemetry table and the top-rung program's flop/byte
    shares."""
    from repro.serving.bucketing import select_kv_bucket
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.telemetry import operator_costs

    cfg = bench_configs()[2]                    # hybrid: both layer kinds
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # coarse profiler: exercises the always-on per-dispatch hook so the
    # smoke can gate its bookkeeping overhead (< 3% of decode wall)
    eng = ServingEngine(cfg, params, slots=2, max_seq=192 + gen_len,
                        decode_block=8, chunk_size=32,
                        profiler=Profiler(mode="coarse"))
    for i, n in enumerate((40, 24)):
        prompt = rng.integers(2, cfg.vocab_size, n).astype(np.int32)
        eng.submit(Request(rid=i, prompt=prompt, max_new=gen_len + 128))
    eng.run(max_iters=10_000)
    assert all(r.status == "ok" for r in eng.finished), \
        [r.status for r in eng.finished]

    bucket = (select_kv_bucket(eng.kv_extent, eng.kv_extent)
              if eng.kv_buckets else None)
    lowered = eng._decode_n.lower(
        eng.params, eng.cache, jnp.asarray(eng.tokens), n=eng.decode_block,
        kv_bucket=bucket, rope_len=eng.rope_len,
        with_sentinel=eng.sentinel)
    shares = operator_costs(lowered.compile())
    snap = eng.telemetry.latency_snapshot()

    decode_keys = [k for k in snap["table"] if k.startswith("decode@")
                   and not k.endswith("@*")]
    print(f"telemetry: arch={snap['arch']} v{snap['version']}, "
          f"{len(decode_keys)} decode bucket(s) {sorted(decode_keys)}; "
          f"top-rung program {shares['flops']:.3g} flops, shares "
          + ", ".join(f"{k}={v['flop_share']:.2f}"
                      for k, v in shares["by_class"].items()))
    return {"per_bucket": snap, "operator_shares": shares,
            "profile": eng.profile_snapshot(),
            "stats": {"iters": eng.stats["iters"],
                      "tpot_ms_est": eng.telemetry.estimate("decode", None),
                      "prefill_tok_ms_est":
                          eng.telemetry.estimate("prefill", None)}}


def _gate_telemetry(telem: dict) -> None:
    """Structural smoke gates on the telemetry record: snapshot schema
    (version + explicit arch), compile samples segregated per rung
    (exactly one first-dispatch each), steady samples present AND
    consistent — the per-rung steady counts must add up to the global
    aggregate and the table's global steady estimate must be warm
    whenever bursts outnumber rungs, so a regression of the
    ``fresh_compile`` gating
    (every sample tagged compile, or none) cannot pass silently — plus
    well-formed operator shares and a bounded coarse-profiler overhead."""
    snap = telem["per_bucket"]
    if snap.get("version") != TRACE_SCHEMA_VERSION or not snap.get("arch"):
        raise SystemExit(
            f"telemetry snapshot missing version/arch: "
            f"{ {k: snap.get(k) for k in ('version', 'arch')} }")
    table = snap["table"]
    decode_keys = [k for k in table if k.startswith("decode@")
                   and not k.endswith("@*")]
    if len(decode_keys) < 2:
        raise SystemExit(
            f"expected >= 2 decode bucket rungs in telemetry, got "
            f"{sorted(decode_keys)}")
    steady_sum = compile_sum = 0
    for k in decode_keys:
        rec = table[k]
        if rec["compile"]["count"] != 1 or rec["steady"]["count"] < 1:
            raise SystemExit(
                f"{k}: compile/steady segregation broken: {rec}")
        steady_sum += rec["steady"]["count"]
        compile_sum += rec["compile"]["count"]
    agg = table["decode@*"]
    if (agg["steady"]["count"] != steady_sum
            or agg["compile"]["count"] != compile_sum):
        raise SystemExit(
            "decode@* aggregate does not reconcile with the rungs: "
            f"steady {agg['steady']['count']} != {steady_sum} or compile "
            f"{agg['compile']['count']} != {compile_sum}")
    bursts = steady_sum + compile_sum
    if bursts > len(decode_keys):
        # more bursts than rungs => steady samples MUST exist and feed
        # the bucket->global fallback the admission estimator reads
        if agg["steady"]["count"] == 0:
            raise SystemExit(
                f"{bursts} decode bursts over {len(decode_keys)} rungs "
                "but zero steady samples: fresh_compile gating regressed")
        if not telem["stats"]["tpot_ms_est"]:
            raise SystemExit(
                "steady decode samples exist but the global decode "
                f"estimate is cold: {telem['stats']}")
    shares = telem["operator_shares"]["by_class"]
    if "gemm" not in shares or "ssm" not in shares:
        raise SystemExit(
            f"hybrid decode program missing gemm/ssm attribution: "
            f"{sorted(shares)}")
    total = sum(c["flop_share"] for c in shares.values())
    if not 0.99 <= total <= 1.01:
        raise SystemExit(f"operator flop shares sum to {total:.4f}")
    prof = telem["profile"]
    decode_wall = prof["coarse"].get("decode", {}).get("wall_ms", 0.0)
    if decode_wall > 0 and prof["overhead_ms"] >= 0.03 * decode_wall:
        raise SystemExit(
            f"coarse profiler overhead {prof['overhead_ms']:.2f}ms is >= "
            f"3% of the {decode_wall:.1f}ms decode wall")
    print(f"telemetry smoke OK: arch={snap['arch']}, rungs "
          f"{sorted(decode_keys)} each with 1 compile + >=1 steady sample "
          f"(aggregate reconciles, {bursts} bursts); operator shares sum "
          f"to {total:.3f}; coarse profiler overhead "
          f"{prof['overhead_ms']:.3f}ms / {decode_wall:.1f}ms decode wall")


class _TickClock:
    """Deterministic engine clock: every read advances a fixed tick, so
    waits and TTFTs are pure functions of the engine's control flow (no
    host-load noise in the scheduling gates)."""

    def __init__(self, tick_ms: float = 1.0):
        self.t = 0.0
        self.tick_s = tick_ms / 1e3

    def __call__(self) -> float:
        self.t += self.tick_s
        return self.t


def bench_scheduling() -> dict:
    """Scheduling-policy record for the longitudinal trajectory: the
    policy-vs-policy per-request bit-identity sweep, a weighted_fair
    sustained-backlog run scored with the Jain fairness index over
    weight-normalized per-class service, and a starvation scenario
    showing weighted_fair aging serves the low class within the bound
    while strict_tiers fails it with ``StarvationTimeout``."""
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.scheduler import (POLICIES, WeightedFairScheduler,
                                         make_scheduler)

    cfg = bench_configs()[0]
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    weights = {0: 1.0, 1: 4.0}

    def engine(scheduler, preempt_after=4):
        return ServingEngine(cfg, params, slots=2, max_seq=96,
                             decode_block=4, chunk_size=16,
                             preempt_after=preempt_after,
                             clock=_TickClock(), scheduler=scheduler)

    # --- policy-vs-policy bit-identity: same mixed-class workload under
    # every policy must decode byte-identical per-request outputs (the
    # tentpole invariant: policy moves work around, never changes it)
    plens = [8, 12, 16, 10, 8, 14, 12, 8]
    prompts = [rng.integers(2, cfg.vocab_size, n).astype(np.int32)
               for n in plens]
    outs = {}
    for policy in POLICIES:
        eng = engine(make_scheduler(policy, weights, None))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=8, priority=i % 2))
        eng.run(max_iters=10_000)
        assert all(r.status == "ok" for r in eng.finished), \
            (policy, [r.status for r in eng.finished])
        outs[policy] = {r.rid: list(r.out) for r in eng.finished}
    bit_identical = all(outs[p] == outs["fifo"] for p in POLICIES)
    assert bit_identical, {p: outs[p] for p in POLICIES}

    # --- weighted fairness under sustained backlog: 12 requests per
    # class, identical shape, 2 slots.  Snapshot per-class service at
    # half completion (while both classes still have queued work) and
    # score Jain over service/weight; preemption is disabled so the gate
    # isolates DRR admission order.  quantum=8 keeps the deficit rounds
    # finer than one 2-request group (16 tokens each) at toy scale.
    fair = engine(WeightedFairScheduler(weights=weights, quantum=8),
                  preempt_after=10**6)
    per_class = 12
    for i in range(2 * per_class):
        prompt = rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
        fair.submit(Request(rid=100 + i, prompt=prompt, max_new=8,
                            priority=i % 2))
    while len(fair.finished) < per_class and fair.step():
        pass
    svc_mid = fair.scheduler.class_service()
    xs = [svc_mid.get(c, 0.0) / w for c, w in weights.items()]
    jain = (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs)) \
        if any(xs) else 0.0
    fair.run(max_iters=10_000)
    assert all(r.status == "ok" for r in fair.finished), \
        [r.status for r in fair.finished]
    summary = fair.telemetry.class_summary()

    # --- starvation bound: one low-class request under a sustained DRIP
    # of fresh high-class arrivals (each new arrival outranks it on
    # credit at weights 1:50, so without aging it would be pushed back
    # until the drip ends).  weighted_fair aging must serve it within
    # the configured bound (no StarvationTimeout, TTFT bounded); the
    # same workload under strict_tiers must fail it with
    # StarvationTimeout — the bound is enforced either way, never
    # silently exceeded.
    starve_ms = 60.0
    backlog = [rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(14)]
    low_prompt = rng.integers(2, cfg.vocab_size, 8).astype(np.int32)

    def starve_run(policy):
        eng = engine(make_scheduler(policy, {0: 1.0, 1: 50.0}, starve_ms),
                     preempt_after=10**6)
        for i in range(4):               # fill both slots + leave queue
            eng.submit(Request(rid=200 + i, prompt=backlog[i], max_new=8,
                               priority=1))
        eng.submit(Request(rid=299, prompt=low_prompt, max_new=8,
                           priority=0))
        nxt = 4
        while eng.step() or eng.queue:
            if nxt < len(backlog):       # fresh high arrival every step
                eng.submit(Request(rid=200 + nxt, prompt=backlog[nxt],
                                   max_new=8, priority=1))
                nxt += 1
            if eng.stats["iters"] > 10_000:
                raise SystemExit(f"{policy} starvation run wedged")
        low = next(r for r in eng.finished if r.rid == 299)
        span = eng.telemetry.class_summary().get(0, {})
        return eng, low, span.get("ttft_p95_ms")

    wf_eng, wf_low, wf_ttft = starve_run("weighted_fair")
    st_eng, st_low, _ = starve_run("strict_tiers")
    elapsed_ms = wf_eng._clock() * 1e3

    row = {
        "policies": list(POLICIES),
        "bit_identical": bit_identical,
        "weighted_fair": {
            "weights": {str(k): v for k, v in weights.items()},
            "quantum": 8,
            "jain_fairness": jain,
            "class_service_mid": {str(k): v for k, v in svc_mid.items()},
            "per_class": {str(k): v for k, v in summary.items()},
        },
        "starvation": {
            "starve_ms": starve_ms,
            "elapsed_ms": elapsed_ms,
            "low_status": wf_low.status,
            "low_ttft_ms": wf_ttft,
            "weighted_fair_timeouts": wf_eng.stats["starvation_timeouts"],
            "strict_tiers_low_status": st_low.status,
            "strict_tiers_timeouts": st_eng.stats["starvation_timeouts"],
        },
    }
    print(f"scheduling: bit-identical across {'/'.join(POLICIES)}; "
          f"jain={jain:.3f} mid-backlog (weights 1:4, service "
          f"{ {k: round(v) for k, v in svc_mid.items()} }); low-class "
          f"TTFT {wf_ttft if wf_ttft is None else round(wf_ttft, 1)}ms "
          f"under weighted_fair (bound {starve_ms:.0f}ms, "
          f"{wf_eng.stats['starvation_timeouts']} timeouts) vs "
          f"strict_tiers status={st_low.status}")
    return row


def _gate_scheduling(sched: dict) -> None:
    """Smoke gates on the scheduling record: outputs bit-identical
    across policies, Jain fairness >= 0.8 for weighted_fair under
    sustained backlog, and the starvation bound honored — the low class
    is served (no timeout) with TTFT within a small multiple of the
    bound under weighted_fair, while strict_tiers enforces the bound by
    failing the outranked waiter with StarvationTimeout."""
    if not sched["bit_identical"]:
        raise SystemExit("per-request outputs differ across policies")
    jain = sched["weighted_fair"]["jain_fairness"]
    if jain < 0.8:
        raise SystemExit(
            f"weighted_fair Jain fairness {jain:.3f} < 0.8: DRR service "
            f"does not track the class weights "
            f"({sched['weighted_fair']['class_service_mid']})")
    st = sched["starvation"]
    if st["low_status"] != "ok" or st["weighted_fair_timeouts"]:
        raise SystemExit(
            f"weighted_fair starved the low class: {st}")
    if st["low_ttft_ms"] is None or \
            st["low_ttft_ms"] > 3.0 * st["starve_ms"]:
        raise SystemExit(
            f"low-class TTFT {st['low_ttft_ms']}ms exceeds 3x the "
            f"{st['starve_ms']:.0f}ms starvation bound: {st}")
    if st["strict_tiers_low_status"] != "timed_out" \
            or not st["strict_tiers_timeouts"]:
        raise SystemExit(
            "strict_tiers did not enforce starve_ms with "
            f"StarvationTimeout: {st}")
    print(f"scheduling smoke OK: bit-identical across "
          f"{'/'.join(sched['policies'])}, jain {jain:.3f} (>= 0.8), "
          f"low-class TTFT {st['low_ttft_ms']:.1f}ms within 3x the "
          f"{st['starve_ms']:.0f}ms bound, strict_tiers timed out the "
          "outranked waiter")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + >=2x assertion (CI perf gate)")
    ap.add_argument("--faults", action="store_true",
                    help="bench the fault-tolerance layer: healthy-path "
                         "sentinel+checkpoint overhead (< 5% gate) and a "
                         "deterministic NaN-recovery run")
    ap.add_argument("--restart", action="store_true",
                    help="bench engine-restart recovery from the durable "
                         "checkpoint store: bit-identical resume, "
                         "recovery wall < 20% of redo-from-scratch")
    ap.add_argument("--ctx", type=int, default=1024,
                    help="--restart: prompt length of the killed request")
    ap.add_argument("--gen-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=0,
                    help="0 = default (1 for --smoke: the paper's "
                         "single-stream edge TPOT setting, else 2)")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    gen_len = 64 if args.smoke else args.gen_len
    batch = args.batch or (1 if args.smoke else 2)
    iters = max(args.iters, 5) if args.smoke else args.iters

    if args.faults:
        # steady-state regime: enough decode per request that the O(1)
        # per-request admission checkpoint amortizes like it does in a
        # real serving window, leaving the periodic sentinel+checkpoint
        # cost as the thing under test
        row = bench_faults(gen_len=max(args.gen_len, 192),
                           iters=max(args.iters, 9))
        _append_run({"bench": "decode", "mode": "faults",
                     "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
                     "results": {"faults": row}})
        return

    if args.restart:
        # long-stream default (128): the killed request must have enough
        # decode behind it that the prefix saved dwarfs the replayed tail
        row = bench_restart(ctx=args.ctx,
                            gen_len=max(args.gen_len, 128))
        _append_run({"bench": "decode", "mode": "restart",
                     "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
                     "results": {"restart": row}})
        return

    results = {}
    for cfg in bench_configs():
        params, cache, first = _prefilled(cfg, batch, 16, 16 + gen_len + 8)
        t_loop, t_fused = time_decoders(cfg, params, cache, first,
                                        gen_len, iters)
        toks = batch * gen_len
        row = {
            "gen_len": gen_len,
            "batch": batch,
            "loop_tpot_ms": 1e3 * t_loop / gen_len,
            "fused_tpot_ms": 1e3 * t_fused / gen_len,
            "loop_tok_s": toks / t_loop,
            "fused_tok_s": toks / t_fused,
            "speedup": t_loop / t_fused,
        }
        results[cfg.name] = row
        print(f"{cfg.name:12s} loop {row['loop_tpot_ms']:7.2f} ms/tok "
              f"({row['loop_tok_s']:8.1f} tok/s) | fused "
              f"{row['fused_tpot_ms']:7.2f} ms/tok "
              f"({row['fused_tok_s']:8.1f} tok/s) | "
              f"speedup {row['speedup']:.2f}x")

    telem = bench_serving_telemetry(gen_len)
    measured = bench_measured_shares()
    sched = bench_scheduling()
    _append_run({"bench": "decode", "smoke": bool(args.smoke),
                 "schema_version": TRACE_SCHEMA_VERSION,
                 "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
                 "results": results, "serving_telemetry": telem,
                 "measured_shares": measured, "scheduling": sched})

    if args.smoke:
        _gate_telemetry(telem)
        _gate_measured_shares(measured)
        _gate_scheduling(sched)
        speedups = [r["speedup"] for r in results.values()]
        gmean = float(np.exp(np.mean(np.log(speedups))))
        worst = min(speedups)
        # gate on the gmean only: per-config wall-clock on a shared host is
        # too noisy for a hard per-config floor (min is still reported)
        if gmean < 2.0:
            raise SystemExit(
                f"fused decode gmean only {gmean:.2f}x over the per-token "
                f"loop (expected >= 2x; min {worst:.2f}x)")
        print(f"smoke OK: gmean speedup {gmean:.2f}x (min {worst:.2f}x)")


if __name__ == "__main__":
    main()
