"""Paper Fig. 1: TTFT & TPOT scaling — Qwen2.5-0.5B (Transformer) vs
Mamba2-780m (SSM) on the RTX 4090 time model.

Claims checked: Transformer ~1.9x faster at short seq; SSM 2.65x (TTFT) /
3x (TPOT) faster at 32K."""
from __future__ import annotations

from repro.core.config import RTX_4090
from benchmarks.common import Emitter, cost_for, time_on

SEQS = (1024, 4096, 8192, 16384, 32768)


def run(em: Emitter) -> None:
    for seq in SEQS:
        tq = time_on(cost_for("qwen2.5-0.5b", "prefill", seq), RTX_4090)
        tm = time_on(cost_for("mamba2-780m", "prefill", seq), RTX_4090)
        em.emit(f"fig1.ttft.qwen2.5-0.5b.s{seq}", tq * 1e6,
                f"ssm_speedup={tq / tm:.2f}x")
        em.emit(f"fig1.ttft.mamba2-780m.s{seq}", tm * 1e6, "")
    for seq in (1024, 32768):
        dq = time_on(cost_for("qwen2.5-0.5b", "decode", seq), RTX_4090)
        dm = time_on(cost_for("mamba2-780m", "decode", seq), RTX_4090)
        em.emit(f"fig1.tpot.qwen2.5-0.5b.s{seq}", dq * 1e6,
                f"ssm_speedup={dq / dm:.2f}x")
        em.emit(f"fig1.tpot.mamba2-780m.s{seq}", dm * 1e6, "")
    # claim summary
    t1k_q = time_on(cost_for("qwen2.5-0.5b", "prefill", 1024), RTX_4090)
    t1k_m = time_on(cost_for("mamba2-780m", "prefill", 1024), RTX_4090)
    t32_q = time_on(cost_for("qwen2.5-0.5b", "prefill", 32768), RTX_4090)
    t32_m = time_on(cost_for("mamba2-780m", "prefill", 32768), RTX_4090)
    em.emit("fig1.claim.short_transformer_advantage", t1k_m / t1k_q * 100,
            f"paper=1.9x_model={t1k_m / t1k_q:.2f}x")
    em.emit("fig1.claim.long_ssm_advantage", t32_q / t32_m * 100,
            f"paper=2.65x_model={t32_q / t32_m:.2f}x")
