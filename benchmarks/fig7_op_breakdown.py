"""Paper Fig. 7: operator-class latency breakdown of Mamba-1 vs Mamba-2
(130m) over sequence length on the consumer GPU.

Claims: SSM-specific ops dominate; Mamba-2's SSM share > Mamba-1's
(d_state 16 -> 128); for Mamba-1 memory ops > arith among non-GEMM, for
Mamba-2 arith > memory.

The curves above are STATIC (roofline cost model).  When
``BENCH_decode.json`` carries a ``measured_shares`` record (written by
``decode_bench.py`` via the profiler-trace sweep), the *measured*
runtime-share curve for the SSM profiling config is emitted next to the
static one — the paper's numbers are measured, so the figure should show
both."""
from __future__ import annotations

import json
import os

from repro.core.config import RTX_4090
from benchmarks.common import Emitter, class_times, cost_for

SEQS = (256, 1024, 4096, 16384, 65536)

_BENCH_DECODE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_decode.json")


def measured_share_records(family: str, path: str = _BENCH_DECODE):
    """Latest ``measured_shares`` records for one arch family from
    ``BENCH_decode.json``; [] when the file / record is absent (the
    figure then plots only the static curve)."""
    try:
        with open(path) as f:
            runs = json.load(f).get("runs", [])
    except (OSError, ValueError):
        return []
    for run in reversed(runs):
        recs = [r for r in run.get("measured_shares", [])
                if r.get("family") == family and r.get("rows")]
        if recs:
            return recs
    return []


def emit_measured(em: Emitter, fig: str, family: str) -> None:
    for rec in measured_share_records(family):
        for row in rec["rows"]:
            sh = row["shares"]
            top = row.get("plurality") or "-"
            em.emit(
                f"{fig}.measured.{rec['arch']}.s{row['context']}",
                row.get("wall_ms", 0.0) * 1e3,
                "ssm={:.0f}%_gemm={:.0f}%_arith={:.0f}%_mem={:.0f}%_"
                "top={}{}".format(
                    100 * sh.get("ssm", 0), 100 * sh.get("gemm", 0),
                    100 * sh.get("arith", 0), 100 * sh.get("memory", 0),
                    top, "_degraded" if row.get("degraded") else ""))


def _shares(model: str, seq: int):
    ct = class_times(cost_for(model, "prefill", seq), RTX_4090)
    tot = sum(ct.values()) or 1.0
    return {k: v / tot for k, v in ct.items()}, tot


def run(em: Emitter) -> None:
    for model in ("mamba-130m", "mamba2-130m"):
        for seq in SEQS:
            sh, tot = _shares(model, seq)
            em.emit(f"fig7.{model}.s{seq}", tot * 1e6,
                    "ssm={:.0f}%_gemm={:.0f}%_arith={:.0f}%_mem={:.0f}%_norm={:.0f}%".format(
                        100 * sh.get("ssm", 0), 100 * sh.get("gemm", 0),
                        100 * sh.get("arith", 0), 100 * sh.get("memory", 0),
                        100 * sh.get("norm", 0)))
    s1, _ = _shares("mamba-130m", 16384)
    s2, _ = _shares("mamba2-130m", 16384)
    em.emit("fig7.claim.mamba2_ssm_share_higher",
            100 * s2.get("ssm", 0),
            f"m1={100 * s1.get('ssm', 0):.0f}%_m2={100 * s2.get('ssm', 0):.0f}%_"
            f"higher={'yes' if s2.get('ssm', 0) > s1.get('ssm', 0) else 'no'}")
    em.emit("fig7.claim.mamba2_arith_gt_memory",
            100 * s2.get("arith", 0),
            f"arith={100 * s2.get('arith', 0):.1f}%_mem={100 * s2.get('memory', 0):.1f}%")
    # measured (profiler-trace) curve next to the static one, when a
    # decode_bench measured-share sweep has been recorded
    emit_measured(em, "fig7", "ssm")
