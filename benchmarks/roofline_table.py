"""Deliverable (g): the roofline table over every dry-run cell.

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun),
derives the three roofline terms on TPU v5e, and emits both CSV rows and
the EXPERIMENTS.md §Roofline markdown table."""
from __future__ import annotations

import glob
import json
import os

from repro.core.config import TPU_V5E
from repro.core.roofline import DEFAULT_LINKS
from benchmarks.common import Emitter, RESULTS_DIR

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def load_cells(mesh: str = "single", dirname: str = "dryrun"):
    base = os.path.join(RESULTS_DIR, dirname)
    cells = []
    for path in sorted(glob.glob(os.path.join(base, f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec, key: str = "hlo"):
    """key: "hlo" (eager ref path) or "hlo_fused" (Pallas-kernel path)."""
    hw = TPU_V5E
    blk = rec.get(key) or rec["hlo"]
    flops, byts = blk["flops"], blk["bytes"]
    coll = blk["coll_bytes"]
    t_c = flops / hw.peak_flops
    t_m = byts / hw.hbm_bw
    t_l = coll / (DEFAULT_LINKS * hw.link_bw)
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    chips = rec.get("chips", 256)
    useful = (rec["model_flops"] / chips) / flops if flops else 0.0
    t_bound = max(t_c, t_m, t_l)
    mfu = (rec["model_flops"] / chips / t_bound / hw.peak_flops
           if t_bound else 0.0)
    return {"arch": rec["arch"], "shape": rec["shape"], "dom": dom,
            "t_c": t_c, "t_m": t_m, "t_l": t_l, "useful": useful,
            "mfu_bound": mfu, "fits": rec["memory"]["fits"],
            "live_gb": rec["memory"]["live_gb"]}


def run(em: Emitter) -> None:
    for mesh in ("single", "multi"):
        for rec in load_cells(mesh):
            tag = f"roofline.{mesh}.{rec['arch']}.{rec['shape']}"
            if not rec.get("applicable", False):
                em.emit(tag, 0.0, f"skip:{rec['skip_reason'][:40]}")
                continue
            if "error" in rec:
                em.emit(tag, 0.0, "ERROR")
                continue
            r = roofline_row(rec)
            em.emit(tag, r["t_c"] * 1e6,
                    f"dom={r['dom']}_tc={r['t_c'] * 1e3:.2f}ms_"
                    f"tm={r['t_m'] * 1e3:.2f}ms_tl={r['t_l'] * 1e3:.2f}ms_"
                    f"useful={r['useful']:.2f}_mfu@bound={r['mfu_bound']:.2f}_"
                    f"fits={r['fits']}")
