"""Paper Table II: the evaluated model suite — reproduced as registry
inventory with parameter counts (checked against the advertised sizes)
and per-family memory character at 32K context."""
from __future__ import annotations

from repro.core.memmodel import inference_memory
from repro.core.registry import get, list_archs, tags_of
from benchmarks.common import Emitter

ADVERTISED = {
    "qwen2.5-0.5b": 0.5e9, "qwen2.5-1.5b": 1.5e9, "phi-3-mini": 3.82e9,
    "llama3.2-1b": 1.24e9, "mamba-130m": 0.13e9, "mamba2-130m": 0.13e9,
    "mamba2-780m": 0.78e9, "zamba2-1.2b": 1.2e9, "falcon-h1-0.5b": 0.5e9,
    # assigned pool
    "zamba2-2.7b": 2.7e9, "hubert-xlarge": 0.96e9,
    "qwen3-moe-235b-a22b": 235e9, "llama4-maverick-400b-a17b": 400e9,
    "glm4-9b": 9.4e9, "llama3-8b": 8.0e9, "gemma3-1b": 1.0e9,
    "smollm-135m": 0.135e9, "mamba2-2.7b": 2.7e9,
    "llava-next-mistral-7b": 7.57e9,
}


def run(em: Emitter) -> None:
    bad = []
    for name in list_archs():
        cfg = get(name)
        n = cfg.param_count()
        adv = ADVERTISED.get(name)
        ratio = n / adv if adv else 0.0
        mem32 = inference_memory(cfg, 1, 32768)
        kv_state = (mem32.kv_cache + mem32.ssm_state) / 1e9
        em.emit(f"table2.{name}", n / 1e6,
                f"family={cfg.family}_params={n / 1e9:.2f}B"
                f"_vs_advertised={ratio:.2f}_kv+state@32k={kv_state:.2f}GB")
        if adv and not (0.7 <= ratio <= 1.35):
            bad.append((name, ratio))
    em.emit("table2.claim.param_counts_within_35pct",
            100.0 * (1 - len(bad) / max(len(ADVERTISED), 1)),
            f"outliers={bad if bad else 'none'}")
