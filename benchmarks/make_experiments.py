"""Generate EXPERIMENTS.md from the dry-run JSONs + bench.csv + the perf
log.  Rerun after any sweep:  PYTHONPATH=src python -m benchmarks.make_experiments
"""
from __future__ import annotations

import csv
import glob
import json
import os

from repro.core.config import SHAPES, TPU_V5E
from repro.core.roofline import DEFAULT_LINKS
from benchmarks.roofline_table import load_cells, roofline_row

HERE = os.path.dirname(__file__)
OUT = os.path.join(HERE, "..", "EXPERIMENTS.md")
BENCH_CSV = os.path.join(HERE, "results", "bench.csv")
PERF_MD = os.path.join(HERE, "perf_log.md")

ARCH_ORDER = [
    "zamba2-2.7b", "hubert-xlarge", "qwen3-moe-235b-a22b",
    "llama4-maverick-400b-a17b", "glm4-9b", "llama3-8b", "gemma3-1b",
    "smollm-135m", "mamba2-2.7b", "llava-next-mistral-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fix_hint(r, rec) -> str:
    cls = rec["hlo"]["by_class"]
    if r["dom"] == "collective":
        return "reshard/overlap the dominant collective (EP all-to-all or DP grad reduce)"
    if r["dom"] == "memory":
        arith = cls.get("arith", {}).get("bytes", 0)
        ssm = cls.get("ssm", {}).get("bytes", 0)
        if ssm > arith:
            return "fuse the SSD scan chain (Pallas kernel path) to cut HBM round-trips"
        return "fuse elementwise/arith chains; keep intermediates bf16"
    if r["useful"] < 0.5:
        return "cut non-model FLOPs: remat policy / causal block-skip in attention"
    return "raise arithmetic intensity per chip (bigger per-device tiles)"


def emit_dryrun_section(lines, mesh):
    lines.append(f"\n### Mesh: {mesh} "
                 f"({'2x16x16=512 chips' if mesh == 'multi' else '16x16=256 chips'})\n")
    lines.append("| arch | shape | status | compile | live GB/chip | fits 16GB "
                 "| HLO GFLOP/chip | coll MB/chip | attn plan |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            path = os.path.join(HERE, "results", "dryrun",
                                f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                lines.append(f"| {arch} | {shape} | MISSING | | | | | | |")
                continue
            rec = json.load(open(path))
            if not rec.get("applicable", False):
                lines.append(f"| {arch} | {shape} | skipped | | | | | | "
                             f"{rec['skip_reason']} |")
                continue
            if "error" in rec:
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            m = rec["memory"]
            plan = rec["plan"]
            lines.append(
                f"| {arch} | {shape} | ok | {rec['compile_s']}s "
                f"| {m['live_gb']:.2f} | {'yes' if m['fits'] else '**no**'} "
                f"| {rec['hlo']['flops'] / 1e9:.1f} "
                f"| {rec['hlo']['coll_bytes'] / 1e6:.1f} "
                f"| {plan['attn_mode']}/kvr{plan['kv_repeat']} |")


def emit_roofline_section(lines):
    lines.append("\n| arch | shape | t_compute | t_memory (eager→fused) "
                 "| t_collective | dominant | useful (6ND/HLO) | MFU@bound "
                 "(eager→fused) | next lever |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    recs = {(r["arch"], r["shape"]): r for r in load_cells("single")
            if r.get("applicable") and "error" not in r}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                continue
            r = roofline_row(rec)
            rf = roofline_row(rec, "hlo_fused")
            lines.append(
                f"| {arch} | {shape} | {r['t_c'] * 1e3:.2f} ms "
                f"| {r['t_m'] * 1e3:.2f}→{rf['t_m'] * 1e3:.2f} ms "
                f"| {r['t_l'] * 1e3:.2f} ms "
                f"| **{rf['dom']}** | {r['useful']:.2f} "
                f"| {r['mfu_bound']:.2f}→{rf['mfu_bound']:.2f} "
                f"| {_fix_hint(rf, rec)} |")


def emit_bench_section(lines):
    if not os.path.exists(BENCH_CSV):
        lines.append("\n*(run `python -m benchmarks.run` to populate)*")
        return
    lines.append("\n| benchmark | value (us) | derived / claim check |")
    lines.append("|---|---|---|")
    with open(BENCH_CSV) as f:
        for row in csv.DictReader(f):
            if row["name"].startswith("roofline."):
                continue
            lines.append(f"| {row['name']} | {float(row['us_per_call']):.1f} "
                         f"| {row['derived']} |")


def main() -> None:
    lines = ["# EXPERIMENTS", ""]
    lines.append(
        "All compiled-artifact numbers come from the CPU-hosted dry-run "
        "(512 fake devices) analyzed with the trip-count-correct HLO cost "
        "model (`repro.core.hlo_analysis`); hardware constants: TPU v5e "
        "197 TF/s bf16, 819 GB/s HBM, 4×50 GB/s ICI, 16 GB HBM. "
        "Paper-figure benches use the RTX 4090 / Jetson Orin Nano time "
        "models per DESIGN.md §3.")
    lines.append("\n## §Dry-run (deliverable e)\n")
    lines.append(
        "Every (arch × shape) cell lowered AND compiled on the production "
        "meshes. Train cells use the per-arch microbatch/optimizer knobs "
        "recorded in `repro.launch.dryrun.TRAIN_MICROBATCHES`; "
        "inference cells donate caches; MoE giants use bf16 Adam moments.")
    for mesh in ("single", "multi"):
        emit_dryrun_section(lines, mesh)

    lines.append("\n## §Roofline (deliverable g) — single pod, per chip\n")
    lines.append(
        "useful = MODEL_FLOPS(6ND train / 2ND inference, N_active for MoE) "
        "per chip ÷ HLO FLOPs per chip. MFU@bound = model FLOP/s per chip "
        "at the perfectly-overlapped roofline bound ÷ peak.")
    emit_roofline_section(lines)

    opt = {(r["arch"], r["shape"]): r
           for r in load_cells("single", dirname="dryrun_opt")
           if r.get("applicable") and "error" not in r}
    if opt:
        lines.append("\n### Optimized configuration (beyond-paper: "
                     "sequence-parallel residual + split-S decode), "
                     "single pod, kernel-fused terms\n")
        lines.append("| arch | shape | bound baseline→opt | MFU@bound "
                     "baseline→opt | t_l baseline→opt | live GB b→o |")
        lines.append("|---|---|---|---|---|---|")
        base = {(r["arch"], r["shape"]): r for r in load_cells("single")
                if r.get("applicable") and "error" not in r}
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                if (arch, shape) not in opt or (arch, shape) not in base:
                    continue
                rb = roofline_row(base[(arch, shape)], "hlo_fused")
                ro = roofline_row(opt[(arch, shape)], "hlo_fused")
                bb = max(rb["t_c"], rb["t_m"], rb["t_l"])
                bo = max(ro["t_c"], ro["t_m"], ro["t_l"])
                lines.append(
                    f"| {arch} | {shape} "
                    f"| {bb * 1e3:.1f}→{bo * 1e3:.1f} ms "
                    f"| {rb['mfu_bound']:.3f}→{ro['mfu_bound']:.3f} "
                    f"| {rb['t_l'] * 1e3:.1f}→{ro['t_l'] * 1e3:.1f} ms "
                    f"| {rb['live_gb']:.1f}→{ro['live_gb']:.1f} |")

    lines.append("\n## §Perf — hillclimb log (baseline → optimized)\n")
    if os.path.exists(PERF_MD):
        lines.append(open(PERF_MD).read())
    else:
        lines.append("*(see benchmarks/perf_log.md)*")

    lines.append("\n## §End-to-end drivers (deliverable b)\n")
    lines.append(
        "* `examples/train_lm.py` — zamba2-style hybrid LM trained 300 steps "
        "on the synthetic needle pipeline with async checkpointing "
        "(restart-verified): loss 7.343 → 6.970 (first/last-20 means), "
        "0 straggler alerts; `--big` selects the ~100M configuration.\n"
        "* `examples/serve_batched.py` — 10 mixed-length requests through "
        "the slot engine (prefill-into-slot + batched decode).\n"
        "* `examples/quickstart.py` / `examples/characterize.py` — registry "
        "→ generate → operator-class breakdown; the paper's Fig. 1/5/7 "
        "story end-to-end (crossover at 1–4K, 12.4 vs 2.0 GB at 32K, "
        "SSM-class 52% at 16K).")
    lines.append("\n## §Paper-figure benchmarks (claim checks)\n")
    lines.append(
        "13/17 claim checks land on the paper's direction AND magnitude "
        "(OOM frontiers within 1.01–1.22×, quantization ratio 3.51× vs "
        "3.5×, energy/crossover ordering, edge SSM-share >55%). Documented "
        "deviations: (1) fig1/fig6 long-context SSM advantage is 2–3× "
        "larger than measured — our time model charges the Transformer "
        "full attention-score traffic while the paper's 4090 runs "
        "FlashAttention-2-class kernels with higher effective bandwidth; "
        "(2) fig7 Mamba-1 vs Mamba-2 SSM-share ordering flips — the "
        "Mamba-1 chunked scan materializes [B,S,C,N] states through the "
        "scan boundary, which our region analysis cannot fold into the "
        "fused kernel (known limitation, see hlo_analysis docstring); "
        "(3) fig6 hybrid throughput 0.95× vs paper 1.54× — the Falcon-H1 "
        "proxy is heavier per token than the real model.")
    emit_bench_section(lines)

    with open(OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({len(lines)} lines)")


if __name__ == "__main__":
    main()
