"""Paper Fig. 8: operator breakdown for hybrid models (Zamba2-1.2B;
Hymba's head-parallel design is out of scope, noted in DESIGN.md).

Claim: hybrids are NOT dominated by SSM ops; GEMM share stays roughly
constant while SSM share diminishes with sequence length.

Static (roofline) curves; when ``BENCH_decode.json`` carries a
``measured_shares`` record, the *measured* hybrid runtime-share curve is
emitted alongside (same trend at profiling scale: ssm share shrinking as
the attention arith share grows with context)."""
from __future__ import annotations

from repro.core.config import RTX_4090
from benchmarks.common import Emitter, class_times, cost_for
from benchmarks.fig7_op_breakdown import emit_measured

SEQS = (1024, 4096, 16384, 49152)


def run(em: Emitter) -> None:
    shares = {}
    for seq in SEQS:
        ct = class_times(cost_for("zamba2-1.2b", "prefill", seq), RTX_4090)
        tot = sum(ct.values()) or 1.0
        sh = {k: v / tot for k, v in ct.items()}
        shares[seq] = sh
        em.emit(f"fig8.zamba2-1.2b.s{seq}", tot * 1e6,
                "ssm={:.0f}%_gemm={:.0f}%_arith={:.0f}%_mem={:.0f}%".format(
                    100 * sh.get("ssm", 0), 100 * sh.get("gemm", 0),
                    100 * sh.get("arith", 0), 100 * sh.get("memory", 0)))
    em.emit("fig8.claim.hybrid_not_ssm_dominated",
            100 * shares[16384].get("ssm", 0),
            f"ssm_share={100 * shares[16384].get('ssm', 0):.0f}%_"
            f"below50={'yes' if shares[16384].get('ssm', 0) < 0.5 else 'no'}")
    em.emit("fig8.claim.ssm_share_diminishes",
            100 * shares[SEQS[-1]].get("ssm", 0),
            f"{100 * shares[SEQS[0]].get('ssm', 0):.0f}%->"
            f"{100 * shares[SEQS[-1]].get('ssm', 0):.0f}%")
    emit_measured(em, "fig8", "hybrid")
