"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (and saves it under
benchmarks/results/bench.csv).  Run:  PYTHONPATH=src python -m benchmarks.run
Optionally:  python -m benchmarks.run --only fig5,fig7
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import Emitter, RESULTS_DIR  # noqa: E402

MODULES = [
    ("fig1", "benchmarks.fig1_ttft_tpot"),
    ("fig1m", "benchmarks.fig1_measured"),
    ("fig3", "benchmarks.fig3_frontier"),
    ("fig5", "benchmarks.fig5_memory"),
    ("fig6", "benchmarks.fig6_energy"),
    ("fig7", "benchmarks.fig7_op_breakdown"),
    ("fig8", "benchmarks.fig8_hybrid_breakdown"),
    ("fig9", "benchmarks.fig9_cross_device"),
    ("quant", "benchmarks.quant_memory"),
    ("table2", "benchmarks.table2_suite"),
    ("kernel", "benchmarks.kernel_bench"),
    ("roofline", "benchmarks.roofline_table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes, e.g. fig5,fig7")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    em = Emitter()
    failures = []
    for name, modpath in MODULES:
        if only and name not in only:
            continue
        try:
            mod = __import__(modpath, fromlist=["run"])
            mod.run(em)
        except Exception:
            failures.append(name)
            print(f"[bench {name} FAILED]\n{traceback.format_exc()}",
                  file=sys.stderr)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    em.save(os.path.join(RESULTS_DIR, "bench.csv"))
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
