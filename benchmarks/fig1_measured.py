"""Empirical (CPU wall-clock) verification of the Fig. 1 crossover on
reduced configs: transformer prefill is super-linear in S, mamba2 linear —
the crossover must appear on ANY device; here we measure it on CPU."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import reduced
from repro.core.registry import get
from repro.models.lm import init_lm_params, lm_forward
from benchmarks.common import Emitter, wall_time


def run(em: Emitter) -> None:
    tf = dataclasses.replace(reduced(get("qwen2.5-0.5b"), d_model=128,
                                     n_units=4), name="tf-r")
    tf = dataclasses.replace(
        tf, attn=dataclasses.replace(tf.attn, dense_cutoff=1 << 30))
    sm = dataclasses.replace(reduced(get("mamba2-780m"), d_model=128,
                                     n_units=4), name="ssm-r")
    key = jax.random.PRNGKey(0)
    p_tf = init_lm_params(tf, key)
    p_sm = init_lm_params(sm, key)
    ratios = []
    for seq in (512, 2048, 8192):
        tok = jnp.ones((1, seq), jnp.int32)
        f_tf = jax.jit(lambda p, t: lm_forward(tf, p, {"tokens": t},
                                               train=False))
        f_sm = jax.jit(lambda p, t: lm_forward(sm, p, {"tokens": t},
                                               train=False))
        t1 = wall_time(f_tf, p_tf, tok)
        t2 = wall_time(f_sm, p_sm, tok)
        ratios.append(t1 / t2)
        em.emit(f"fig1m.prefill.transformer.s{seq}", t1 * 1e6,
                f"vs_ssm={t1 / t2:.2f}x")
        em.emit(f"fig1m.prefill.mamba2.s{seq}", t2 * 1e6, "")
    em.emit("fig1m.claim.scaling_inversion", ratios[-1] / ratios[0] * 100,
            f"ratio_grew={ratios[0]:.2f}->{ratios[-1]:.2f}"
            f"_monotone={'yes' if ratios[-1] > ratios[0] else 'no'}")
