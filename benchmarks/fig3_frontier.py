"""Paper Fig. 3: accuracy-latency frontier at 57K prefill (≈1.5B class).

Accuracy cannot be reproduced without trained weights (we cite the paper's
numbers); the latency axis is reproduced with the RTX 4090 time model.
Claim: hybrid keeps ~2.8x TTFT speedup over the Transformer at 57K."""
from __future__ import annotations

from repro.core.config import RTX_4090
from benchmarks.common import Emitter, cost_for, time_on

PAPER_ACC = {"qwen2.5-1.5b": 61.1, "mamba2-780m": 36.3,
             "falcon-h1-0.5b": None}      # 5-shot MMLU (paper-cited)


def run(em: Emitter) -> None:
    t = {}
    for m in ("qwen2.5-1.5b", "mamba2-780m", "falcon-h1-0.5b"):
        t[m] = time_on(cost_for(m, "prefill", 57344), RTX_4090)
        acc = PAPER_ACC.get(m)
        em.emit(f"fig3.ttft57k.{m}", t[m] * 1e6,
                f"paper_mmlu={acc if acc else 'n/a'}")
    em.emit("fig3.claim.hybrid_ttft_speedup",
            t["qwen2.5-1.5b"] / t["falcon-h1-0.5b"] * 100,
            f"paper=2.8x_model={t['qwen2.5-1.5b'] / t['falcon-h1-0.5b']:.2f}x")
