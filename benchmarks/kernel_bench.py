"""Kernel-level benchmarks.

1. Chunked-dual SSD vs sequential scan: the paper's core operator insight
   (hardware-aware reformulation) measured as real CPU wall-clock — the
   chunked form's matmul structure wins on any hardware with dense units.
2. VMEM working-set check for the Pallas SSD kernel block shapes (static).
3. Serving kernels (paper Fig. 7 operator breakdown coverage): the fused
   mamba1/mamba2 decode steps and the chunk-prefill attention shape (a
   query chunk at a KV offset against a long cache prefix).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_fused.ref import (mamba1_decode_fused_ref,
                                            mamba2_decode_fused_ref)
from repro.kernels.flash.ref import attention_ref
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_sequential
from benchmarks.common import Emitter, wall_time

VMEM_BYTES = 128 * 1024 * 1024   # v5e VMEM per core ~128MB usable window


def run(em: Emitter) -> None:
    key = jax.random.PRNGKey(0)
    b, s, h, p, g, n = 1, 4096, 8, 64, 1, 64
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    D = jax.random.normal(ks[5], (h,))
    f_seq = jax.jit(lambda *a: ssd_sequential(*a)[0])
    f_chk = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=128)[0])
    t_seq = wall_time(f_seq, x, dt, A, Bm, Cm, D)
    t_chk = wall_time(f_chk, x, dt, A, Bm, Cm, D)
    em.emit("kernel.ssd.sequential.s4096", t_seq * 1e6, "")
    em.emit("kernel.ssd.chunked.s4096", t_chk * 1e6,
            f"speedup={t_seq / t_chk:.1f}x_over_sequential")
    # Pallas SSD kernel block working set (chunk=128, P=64, N=128):
    chunk, pp, nn = 128, 64, 128
    ws = (chunk * pp + 2 * chunk * nn + chunk * 1 + chunk * chunk
          + pp * nn) * 4
    em.emit("kernel.ssd.vmem_working_set", ws,
            f"{ws / 1024:.0f}KB_fits_vmem={'yes' if ws < VMEM_BYTES else 'no'}")
    # flash kernel block (bq=bk=512, d=128): q,k,v,scores f32 + acc
    bq = bk = 512
    d = 128
    ws2 = (bq * d + 2 * bk * d + bq * bk + bq * d) * 4
    em.emit("kernel.flash.vmem_working_set", ws2,
            f"{ws2 / 1024:.0f}KB_fits_vmem={'yes' if ws2 < VMEM_BYTES else 'no'}")

    # chunk-prefill attention shape: a 512-token query chunk at a KV offset
    # against an 8K cache prefix (the serving prefill inner loop)
    kq = jax.random.split(key, 3)
    d = 64
    qc = jax.random.normal(kq[0], (1, 8, 512, d), jnp.float32)
    kc = jax.random.normal(kq[1], (1, 2, 8192, d), jnp.float32)
    vc = jax.random.normal(kq[2], (1, 2, 8192, d), jnp.float32)
    off = jnp.full((1,), 7680, jnp.int32)            # last chunk of 8K
    f_chunk = jax.jit(lambda q, k, v, o: attention_ref(
        q, k, v, causal=True, q_offset=o))
    t_chunk = wall_time(f_chunk, qc, kc, vc, off)
    em.emit("kernel.flash.chunk_prefill.q512_kv8192", t_chunk * 1e6,
            "offset_causal_chunk_vs_full_cache")

    # fused decode steps (serving decode inner loop, per engine iteration)
    bsz, dm = 8, 256
    di, nh, pp, ng, nn = 2 * dm, (2 * dm) // 64, 64, 1, 64
    conv_k = 4
    conv_dim = di + 2 * ng * nn
    km = jax.random.split(key, 9)
    f_m2 = jax.jit(lambda cs, hs, xbc, w, bb, dtr, dtb, al, dd:
                   mamba2_decode_fused_ref(cs, hs, xbc, w, bb, dtr, dtb,
                                           al, dd, n_groups=ng, d_state=nn,
                                           headdim=pp))
    t_m2 = wall_time(
        f_m2,
        jax.random.normal(km[0], (bsz, conv_k - 1, conv_dim)),
        jax.random.normal(km[1], (bsz, nh, pp, nn)),
        jax.random.normal(km[2], (bsz, conv_dim)),
        jax.random.normal(km[3], (conv_dim, conv_k)),
        jnp.zeros((conv_dim,)),
        jax.random.normal(km[4], (bsz, nh)),
        jnp.zeros((nh,)), jnp.zeros((nh,)), jnp.ones((nh,)))
    em.emit("kernel.decode_fused.mamba2.b8_d256", t_m2 * 1e6,
            "fused_conv_shift+ssd_state_update")
    dtr_rank, ns1 = 16, 16
    f_m1 = jax.jit(lambda cs, hs, xi, w, bb, xp, dp, dtb, al, dd:
                   mamba1_decode_fused_ref(cs, hs, xi, w, bb, xp, dp, dtb,
                                           al, dd, d_state=ns1,
                                           dt_rank=dtr_rank))
    t_m1 = wall_time(
        f_m1,
        jax.random.normal(km[5], (bsz, conv_k - 1, di)),
        jax.random.normal(km[6], (bsz, di, ns1)),
        jax.random.normal(km[7], (bsz, di)),
        jax.random.normal(km[8], (di, conv_k)),
        jnp.zeros((di,)),
        jax.random.normal(km[0], (di, dtr_rank + 2 * ns1)),
        jax.random.normal(km[1], (dtr_rank, di)),
        jnp.zeros((di,)),
        jax.random.normal(km[2], (di, ns1)),
        jnp.ones((di,)))
    em.emit("kernel.decode_fused.mamba1.b8_d256", t_m1 * 1e6,
            "fused_conv_shift+s6_state_update")
