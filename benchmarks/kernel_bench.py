"""Kernel-level benchmarks.

1. Chunked-dual SSD vs sequential scan: the paper's core operator insight
   (hardware-aware reformulation) measured as real CPU wall-clock — the
   chunked form's matmul structure wins on any hardware with dense units.
2. VMEM working-set check for the Pallas SSD kernel block shapes (static).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_sequential
from benchmarks.common import Emitter, wall_time

VMEM_BYTES = 128 * 1024 * 1024   # v5e VMEM per core ~128MB usable window


def run(em: Emitter) -> None:
    key = jax.random.PRNGKey(0)
    b, s, h, p, g, n = 1, 4096, 8, 64, 1, 64
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    D = jax.random.normal(ks[5], (h,))
    f_seq = jax.jit(lambda *a: ssd_sequential(*a)[0])
    f_chk = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=128)[0])
    t_seq = wall_time(f_seq, x, dt, A, Bm, Cm, D)
    t_chk = wall_time(f_chk, x, dt, A, Bm, Cm, D)
    em.emit("kernel.ssd.sequential.s4096", t_seq * 1e6, "")
    em.emit("kernel.ssd.chunked.s4096", t_chk * 1e6,
            f"speedup={t_seq / t_chk:.1f}x_over_sequential")
    # Pallas SSD kernel block working set (chunk=128, P=64, N=128):
    chunk, pp, nn = 128, 64, 128
    ws = (chunk * pp + 2 * chunk * nn + chunk * 1 + chunk * chunk
          + pp * nn) * 4
    em.emit("kernel.ssd.vmem_working_set", ws,
            f"{ws / 1024:.0f}KB_fits_vmem={'yes' if ws < VMEM_BYTES else 'no'}")
    # flash kernel block (bq=bk=512, d=128): q,k,v,scores f32 + acc
    bq = bk = 512
    d = 128
    ws2 = (bq * d + 2 * bk * d + bq * bk + bq * d) * 4
    em.emit("kernel.flash.vmem_working_set", ws2,
            f"{ws2 / 1024:.0f}KB_fits_vmem={'yes' if ws2 < VMEM_BYTES else 'no'}")
