import os

import pytest

# Smoke tests and benches must see exactly 1 device (the dry-run sets its
# own 512-device flag in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavyweight kernel-parity sweep — skipped in tier-1 unless "
        "REPRO_RUN_SLOW=1 (scripts/verify.sh sets it)")


def pytest_collection_modifyitems(config, items):
    """Tier-1 (`python -m pytest -x -q`) must stay under the CI container's
    5-minute budget: the exhaustive kernel-parity sweeps run only when
    REPRO_RUN_SLOW=1 (scripts/verify.sh); a thin parity smoke per kernel
    stays unmarked so tier-1 still exercises every code path."""
    if os.environ.get("REPRO_RUN_SLOW"):
        return
    skip = pytest.mark.skip(reason="slow sweep; REPRO_RUN_SLOW=1 enables")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
