import os

# Smoke tests and benches must see exactly 1 device (the dry-run sets its
# own 512-device flag in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "float32")
