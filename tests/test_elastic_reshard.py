"""Elastic scaling: a checkpoint written under one mesh restores onto a
different mesh (different device count / sharding) with identical values.
Runs in a subprocess with 8 fake host devices."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.ckpt import restore, save

    ckpt_dir = sys.argv[1]
    at = getattr(jax.sharding, "AxisType", None)  # absent on older jax
    kw = (lambda n: {"axis_types": (at.Auto,) * n}) if at else (lambda n: {})
    mesh_a = jax.make_mesh((8,), ("model",), **kw(1))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"), **kw(2))

    # "train" on mesh A: params sharded 8-way on the last dim
    w = jnp.arange(16 * 64, dtype=jnp.float32).reshape(16, 64)
    wa = jax.device_put(w, NamedSharding(mesh_a, P(None, "model")))
    tree = {"w": wa, "step": jnp.int32(7)}
    save(ckpt_dir, 7, tree)

    # "restart" on mesh B with a different layout (elastic rescale)
    shard_b = {"w": NamedSharding(mesh_b, P("data", "model")),
               "step": NamedSharding(mesh_b, P())}
    out = restore(ckpt_dir, tree, shardings=shard_b)
    assert out["w"].sharding == shard_b["w"], out["w"].sharding
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
    assert int(out["step"]) == 7
    print("ELASTIC_OK")
""")


def test_elastic_mesh_to_mesh_restore(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=240)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ELASTIC_OK" in r.stdout
