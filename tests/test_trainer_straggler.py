"""Straggler-mitigation hook: an artificially slow step must be detected."""
import time

import numpy as np
import pytest

from repro.core.config import ModelConfig, SSMConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


@pytest.mark.slow
def test_straggler_detected():
    cfg = ModelConfig(name="t", family="ssm", n_layers=2, d_model=32, d_ff=0,
                      vocab_size=64,
                      ssm=SSMConfig(d_state=8, headdim=8, chunk=8),
                      layer_pattern=("mamba2",), vocab_pad_multiple=16)
    t = Trainer(cfg, OptConfig(), TrainerConfig(steps=14, ckpt_every=0,
                                                straggler_factor=2.5,
                                                log_every=1000),
                seq_len=32, global_batch=2)
    base_fn = t.batch_fn

    def slow_fn(step):
        if step == 10:       # simulate one slow host at step 10
            time.sleep(1.0)
        return base_fn(step)

    t.batch_fn = slow_fn
    logs = []
    st = t.run(log=logs.append)
    assert st.straggler_steps >= 1
    assert any("straggler" in l for l in logs)
