"""Engine-level chunked-prefill regressions: interleaved prefill/decode
(no head-of-line blocking), mixed-length admission without same-length
grouping, preemption via host offload/restore (bucketed caches included),
and rolling-window architectures on the SAME unified chunked path —
ring-buffer prefill, starvation preemption across a wrapped ring cursor,
and correct cache sizing when window and max_seq disagree.  The one-shot
grouped fallback is gone; encoder/audio configs are rejected at engine
construction."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import (init_lm_cache, init_lm_params, lm_decode_step,
                             lm_forward, lm_prefill)
from repro.serving.engine import Request, ServingEngine, greedy_generate

KEY = jax.random.PRNGKey(0)


def _hybrid_cfg():
    return ModelConfig(name="hyb", family="hybrid", n_layers=4, d_model=64,
                       d_ff=0, vocab_size=97,
                       ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                       layer_pattern=("mamba2", "mamba2+shared"),
                       shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                              head_dim=16),
                       shared_attn_d_ff=128, vocab_pad_multiple=16)


def _local_cfg():
    # fp32 compute: the engine's ring-buffer chunked prefill and the solo
    # one-shot baseline reduce in different orders; fp32 keeps the
    # token-for-token comparison deterministic (no bf16 argmax near-ties)
    return ModelConfig(name="loc", family="dense", n_layers=2, d_model=64,
                       d_ff=128, vocab_size=97, compute_dtype="float32",
                       attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                       sliding_window=8),
                       layer_pattern=("local", "dense"),
                       vocab_pad_multiple=16)


def _solo(cfg, params, prompt, max_seq, n):
    out, _ = greedy_generate(cfg, params, {"tokens": jnp.asarray(prompt[None])},
                             max_seq=max_seq, gen_len=n)
    return np.asarray(out[0])


@pytest.mark.slow
def test_mixed_length_chunked_admission_matches_solo():
    """Heterogeneous prompt lengths admitted as ONE padded prefill group
    (chunked, no same-length grouping) must decode exactly like solo
    batch-1 runs.  Slow sweep: the head-of-line test below covers the
    mixed-length interleave path in tier-1."""
    cfg = _hybrid_cfg()
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (9, 17, 12, 9, 23)]
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, decode_block=4,
                        chunk_size=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=10))
    done = {r.rid: r.out for r in eng.run()}
    assert len(done) == len(prompts)
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            np.asarray(done[i][:10]), _solo(cfg, params, p, 64, 10),
            err_msg=f"rid={i} diverged from solo decode")


@pytest.mark.slow
def test_no_head_of_line_blocking():
    """A long prompt prefilling chunk-by-chunk must not stall decode: on
    every engine iteration where a prefill chunk ran alongside live slots,
    decode must have emitted tokens."""
    cfg = _hybrid_cfg()
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(7)
    long_p = rng.integers(2, cfg.vocab_size, 96).astype(np.int32)
    shorts = [rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
              for _ in range(3)]
    eng = ServingEngine(cfg, params, slots=2, max_seq=160, decode_block=4,
                        chunk_size=8)
    eng.submit(Request(rid=0, prompt=long_p, max_new=8))
    for i, p in enumerate(shorts):
        eng.submit(Request(rid=i + 1, prompt=p, max_new=12))
    done = {r.rid: r.out for r in eng.run()}
    assert len(done) == 4
    # the long prompt really was chunked across iterations ...
    assert eng.stats["prefill_chunks"] >= 96 // 8
    # ... and decode progressed on every iteration it shared with a chunk
    assert eng.stats["interleave_iters"] > 0
    assert (eng.stats["interleave_decode_iters"]
            == eng.stats["interleave_iters"]), eng.stats
    np.testing.assert_array_equal(np.asarray(done[0][:8]),
                                  _solo(cfg, params, long_p, 160, 8))
    for i, p in enumerate(shorts):
        np.testing.assert_array_equal(np.asarray(done[i + 1][:12]),
                                      _solo(cfg, params, p, 160, 12))


@pytest.mark.slow
def test_preemption_offload_restore_exact_resume():
    """When the queue starves, the engine must offload the slot with the
    most remaining decode work through serving/cache.py and later restore
    it with its output stream bit-identical to an uninterrupted run."""
    cfg = _hybrid_cfg()
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(5)
    p_long = rng.integers(2, cfg.vocab_size, 11).astype(np.int32)
    p_short = rng.integers(2, cfg.vocab_size, 7).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, max_seq=96, decode_block=2,
                        chunk_size=8, preempt_after=2)
    eng.submit(Request(rid=0, prompt=p_long, max_new=40))
    eng.submit(Request(rid=1, prompt=p_short, max_new=6))
    done = {r.rid: r for r in eng.run()}
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["restores"] == eng.stats["preemptions"]
    assert done[0].preemptions >= 1
    np.testing.assert_array_equal(np.asarray(done[0].out[:40]),
                                  _solo(cfg, params, p_long, 96, 40))
    np.testing.assert_array_equal(np.asarray(done[1].out[:6]),
                                  _solo(cfg, params, p_short, 96, 6))
    # preempted requests must not linger on device while waiting
    assert all(r.blob is None for r in done.values())


@pytest.mark.slow
def test_rolling_window_unified_chunked_admission():
    """Sliding-window archs admit through the SAME chunked pipeline as
    everything else (ring-buffer prefill — no one-shot fallback): prompts
    longer than the window must chunk, wrap the ring, and still match
    solo decode token for token."""
    cfg = _local_cfg()
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (6, 21, 11)]                   # 21, 11 > window=8
    eng = ServingEngine(cfg, params, slots=2, max_seq=48, decode_block=4,
                        chunk_size=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=8))
    done = {r.rid: r.out for r in eng.run()}
    assert len(done) == len(prompts)
    # the long prompts really went through the chunk step, not one-shot
    assert eng.stats["prefill_chunks"] >= 3
    # bucket ladder capped at the model's KV extent (= max_seq here: the
    # dense layers dominate the window)
    assert eng.kv_buckets and eng.kv_extent == 48
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(np.asarray(done[i][:8]),
                                      _solo(cfg, params, p, 48, 8))


def test_pure_rolling_ladder_caps_at_window():
    """A pure-windowed arch's bucket ladder tops out at the WINDOW, not
    max_seq: chunk attention is O(window) however long the prompt, and the
    rope tables must still cover positions past the window."""
    cfg = ModelConfig(name="locpure2", family="dense", n_layers=2,
                      d_model=64, d_ff=128, vocab_size=97,
                      compute_dtype="float32",
                      attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                      sliding_window=8),
                      layer_pattern=("local",), vocab_pad_multiple=16)
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(9)
    prompt = rng.integers(2, cfg.vocab_size, 30).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=2, max_seq=96, decode_block=4,
                        chunk_size=8)
    assert eng.kv_extent == 8 and eng.rope_len == 96
    eng.submit(Request(rid=0, prompt=prompt, max_new=10))
    done = {r.rid: r.out for r in eng.run()}
    assert eng.buckets_used == {8}, eng.buckets_used
    np.testing.assert_array_equal(np.asarray(done[0][:10]),
                                  _solo(cfg, params, prompt, 96, 10))


def test_submit_rejects_invalid_prompts():
    """Oversized/empty prompts must fail loudly at submit time, not corrupt
    an in-flight admission group (which would strand co-batched requests
    and leave reserved slots stuck forever)."""
    cfg = _hybrid_cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=2, max_seq=32, decode_block=4,
                        chunk_size=8)
    rng = np.random.default_rng(0)
    with np.testing.assert_raises(ValueError):
        eng.submit(Request(rid=0, prompt=rng.integers(
            2, cfg.vocab_size, 32).astype(np.int32), max_new=4))
    with np.testing.assert_raises(ValueError):
        eng.submit(Request(rid=1, prompt=np.zeros((0,), np.int32), max_new=4))
    # valid work still flows after the rejections
    eng.submit(Request(rid=2, prompt=rng.integers(
        2, cfg.vocab_size, 9).astype(np.int32), max_new=4))
    done = eng.run()
    assert [r.rid for r in done] == [2] and len(done[0].out) == 4


def test_engine_rejects_non_autoregressive_archs():
    """Encoder (bidirectional) configs have no decode step: the slot
    engine must refuse them loudly at construction — the old silent
    one-shot fallback is gone."""
    enc = ModelConfig(name="enc", family="encoder", n_layers=2, d_model=64,
                      d_ff=128, vocab_size=97,
                      attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                                      causal=False),
                      layer_pattern=("encoder",), vocab_pad_multiple=16)
    params = init_lm_params(enc, KEY)
    with pytest.raises(ValueError, match="no autoregressive serving path"):
        ServingEngine(enc, params, slots=2, max_seq=48)


@pytest.mark.slow
def test_rolling_window_preempts_across_ring_wrap():
    """Starvation preemption on a rolling-window arch, preempted AFTER the
    ring cursor has wrapped (pos > window at offload): the blob carries
    full ring rows + pos (the cursor), so the restored request must resume
    bit-exactly and finish identical to an uninterrupted solo run."""
    cfg = _local_cfg()
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(2)
    p_long = rng.integers(2, cfg.vocab_size, 11).astype(np.int32)
    p_short = rng.integers(2, cfg.vocab_size, 7).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=1, max_seq=96, decode_block=2,
                        chunk_size=8, preempt_after=2)
    eng.submit(Request(rid=0, prompt=p_long, max_new=40))
    eng.submit(Request(rid=1, prompt=p_short, max_new=6))
    done = {r.rid: r for r in eng.run()}
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["restores"] == eng.stats["preemptions"]
    # prompt len 11 > window 8: the cursor had wrapped before any preempt
    assert done[0].preemptions >= 1 and done[0].resume_pos > 8
    np.testing.assert_array_equal(np.asarray(done[0].out[:40]),
                                  _solo(cfg, params, p_long, 96, 40))
    np.testing.assert_array_equal(np.asarray(done[1].out[:6]),
                                  _solo(cfg, params, p_short, 96, 6))


@pytest.mark.slow
def test_window_larger_than_max_seq_cache_sizing():
    """Regression for the rolling-cache sizing bug: with window > max_seq,
    ``init_attn_cache`` used to clamp the cache to max_seq rows while
    keeping non-modular decode writes — every token past max_seq was
    silently dropped and decode went stale.  The rolling cache must hold
    the full window; prefill+decode must match teacher-forced full
    forwards exactly."""
    cfg = ModelConfig(name="locpure", family="dense", n_layers=2, d_model=64,
                      d_ff=128, vocab_size=97,
                      attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                      sliding_window=16),
                      layer_pattern=("local",), vocab_pad_multiple=16)
    params = init_lm_params(cfg, KEY)
    MS, plen, n = 12, 8, 7
    cache = init_lm_cache(cfg, 1, MS)
    kleaf = cache["segments"][0][0]["k"]
    assert kleaf.shape[2] == 16, "rolling cache must span the full window"
    fwd = jax.jit(partial(lm_forward, cfg, train=False))
    prompt = np.random.default_rng(0).integers(2, cfg.vocab_size,
                                               plen).astype(np.int32)
    seq, gt = list(prompt), []
    for _ in range(n):
        lg = fwd(params, {"tokens": jnp.asarray(np.asarray(seq)[None])})
        nxt = int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))
        gt.append(nxt)
        seq.append(nxt)
    lg, cache = jax.jit(partial(lm_prefill, cfg))(
        params, {"tokens": jnp.asarray(prompt[None])}, cache)
    out = [int(jnp.argmax(lg[0, -1, :cfg.vocab_size]))]
    step = jax.jit(partial(lm_decode_step, cfg))
    for _ in range(n - 1):
        lg, cache = step(params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0, 0, :cfg.vocab_size])))
    assert out == gt, f"stale decode past max_seq: {out} vs {gt}"


@pytest.mark.slow
def test_preemption_restore_across_buckets():
    """Bucketed caches + preemption: a request evicted while the engine
    decodes in one KV bucket must resume bit-exactly after the engine has
    moved to a different (larger) bucket — the offload blob carries full
    cache rows, not bucket-sliced ones."""
    cfg = _hybrid_cfg()
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(5)
    p_long = rng.integers(2, cfg.vocab_size, 11).astype(np.int32)
    p_short = rng.integers(2, cfg.vocab_size, 7).astype(np.int32)
    # max_seq 256 gives a two-rung ladder (128, 256); the long request is
    # preempted early (bucket 128) and finishes deep in the 256 rung
    eng = ServingEngine(cfg, params, slots=1, max_seq=256, decode_block=8,
                        chunk_size=8, preempt_after=2)
    assert eng.kv_buckets
    eng.submit(Request(rid=0, prompt=p_long, max_new=140))
    eng.submit(Request(rid=1, prompt=p_short, max_new=6))
    done = {r.rid: r for r in eng.run()}
    assert eng.stats["preemptions"] >= 1
    assert done[0].preemptions >= 1
    assert len(eng.buckets_used) >= 2, eng.buckets_used
    np.testing.assert_array_equal(np.asarray(done[0].out[:140]),
                                  _solo(cfg, params, p_long, 256, 140))
    np.testing.assert_array_equal(np.asarray(done[1].out[:6]),
                                  _solo(cfg, params, p_short, 256, 6))


def test_max_new_respected_with_blocks():
    """decode_block > max_new must not over-emit (chunked admission)."""
    cfg = _hybrid_cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=2, max_seq=48, decode_block=8,
                        chunk_size=8)
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(2, cfg.vocab_size,
                                               6).astype(np.int32),
                           max_new=3))
    done = eng.run()
    assert all(len(r.out) == 3 for r in done)
