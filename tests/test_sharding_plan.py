"""Sharding-plan unit tests on an AbstractMesh (no devices needed)."""
import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ASSIGNED
from repro.core.config import SHAPES
from repro.core.registry import get
from repro.core.workload import applicable
from repro.distributed.sharding import plan_sharding, zero1_rules


def _mesh(multi_pod=False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    names = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        # older jax: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, shape)))


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape", sorted(SHAPES))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_plan_builds_for_every_cell(arch, shape, multi_pod):
    cfg, wl = get(arch), SHAPES[shape]
    ok, why = applicable(cfg, wl)
    if not ok:
        pytest.skip(why)
    plan = plan_sharding(cfg, wl, _mesh(multi_pod))
    # head-mode requires divisibility; otherwise seq-mode must be chosen
    if cfg.attn is not None:
        if plan.attn_mode == "head":
            assert cfg.attn.n_heads % 16 == 0
        else:
            assert cfg.attn.n_heads % 16 != 0
    # batch sharding divides the global batch
    bsz = wl.global_batch
    assert bsz % plan.data_size == 0 or plan.data_size == 1


def test_spec_divisibility_fallback():
    plan = plan_sharding(get("llama3-8b"), SHAPES["train_4k"], _mesh())
    # 100 doesn't divide 16 -> replicated
    assert plan.spec(("ff",), (100,)) == P(None)
    assert plan.spec(("ff",), (14336,)) == P("model")
    # one mesh axis never used twice
    s = plan.spec(("ff", "ff"), (160, 320))
    assert s == P("model", None)


def test_seq_mode_for_small_heads():
    plan = plan_sharding(get("gemma3-1b"), SHAPES["prefill_32k"], _mesh())
    assert plan.attn_mode == "seq"
    plan2 = plan_sharding(get("smollm-135m"), SHAPES["train_4k"], _mesh())
    assert plan2.attn_mode == "seq"


def test_kv_repeat_exactness_rules():
    plan = plan_sharding(get("llama3-8b"), SHAPES["train_4k"], _mesh())
    assert plan.attn_mode == "head" and plan.kv_repeat == 2    # kv 8 -> 16
    plan = plan_sharding(get("glm4-9b"), SHAPES["train_4k"], _mesh())
    assert plan.kv_repeat == 8                                  # kv 2 -> 16


def test_zero1_adds_data_axis():
    plan = plan_sharding(get("llama3-8b"), SHAPES["train_4k"], _mesh())
    z = zero1_rules(plan)
    spec = z.spec(("embed", "ff"), (4096, 14336))
    assert spec == P("data", "model")


def test_fsdp_plan_llama4():
    plan = plan_sharding(get("llama4-maverick-400b-a17b"),
                         SHAPES["train_4k"], _mesh())
    assert plan.attn_mode == "seq"          # 40 heads !% 16
    assert plan.param_rules["embed"] == "data"
