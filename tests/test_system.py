"""End-to-end behaviour tests: training convergence, fault-tolerant
restart, serving engine, memory model sanity vs paper claims."""
import numpy as np
import pytest

from repro.core.config import (AttnConfig, ModelConfig, RTX_4090, SSMConfig)
from repro.core.memmodel import inference_memory, max_seq_len
from repro.core.registry import get
from repro.serving.engine import Request, ServingEngine, greedy_generate
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_hybrid():
    return ModelConfig(
        name="sys-hybrid", family="hybrid", n_layers=4, d_model=64, d_ff=0,
        vocab_size=64, ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
        shared_attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        shared_attn_d_ff=128, layer_pattern=("mamba2", "mamba2+shared"),
        vocab_pad_multiple=16)


@pytest.mark.slow
def test_training_reduces_loss():
    t = Trainer(_tiny_hybrid(), OptConfig(lr=3e-3),
                TrainerConfig(steps=30, ckpt_every=0, log_every=100),
                seq_len=64, global_batch=8)
    st = t.run(log=lambda *_: None)
    first = np.mean(st.losses[:5])
    last = np.mean(st.losses[-5:])
    assert last < first - 0.05, (first, last)


@pytest.mark.slow
def test_restart_resumes_identically(tmp_path):
    """Train 10 steps with a checkpoint at 5; a fresh trainer restored at 5
    must reproduce steps 6-10 exactly (deterministic data + optimizer)."""
    cfg = _tiny_hybrid()
    kw = dict(seq_len=32, global_batch=4)
    t1 = Trainer(cfg, OptConfig(lr=1e-3),
                 TrainerConfig(steps=10, ckpt_every=5, log_every=100,
                               ckpt_dir=str(tmp_path)), **kw)
    s1 = t1.run(log=lambda *_: None)
    t2 = Trainer(cfg, OptConfig(lr=1e-3),
                 TrainerConfig(steps=10, ckpt_every=100, log_every=100,
                               ckpt_dir=str(tmp_path)), **kw)
    assert t2.maybe_restore() and t2.state.step in (5, 10)
    if t2.state.step == 10:   # final checkpoint also saved; re-restore at 5
        from repro.checkpoint.ckpt import restore
        tree = {"params": t2.params, "opt": t2.opt_state}
        r = restore(str(tmp_path), tree, step=5)
        t2.params, t2.opt_state = r["params"], r["opt"]
        t2.state.step = 5
    s2 = t2.run(log=lambda *_: None)
    np.testing.assert_allclose(s1.losses[5:], s2.losses, rtol=1e-5)


def test_serving_engine_continuous_batching():
    cfg = _tiny_hybrid()
    t = Trainer(cfg, OptConfig(), TrainerConfig(steps=1, ckpt_every=0),
                seq_len=16, global_batch=2)
    eng = ServingEngine(cfg, t.params, slots=2, max_seq=32)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(6, dtype=np.int32) + 2,
                           max_new=4))
    done = eng.run()
    assert sorted(r.rid for r in done) == list(range(5))
    assert all(len(r.out) == 4 for r in done)


def test_greedy_generate_shapes():
    cfg = _tiny_hybrid()
    t = Trainer(cfg, OptConfig(), TrainerConfig(steps=1, ckpt_every=0),
                seq_len=16, global_batch=2)
    import jax.numpy as jnp
    toks, _ = greedy_generate(cfg, t.params,
                              {"tokens": jnp.ones((2, 8), jnp.int32)},
                              max_seq=24, gen_len=6)
    assert toks.shape == (2, 6)
    assert (np.asarray(toks) < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# paper-claim sanity on the analytic memory model (Fig. 5)
# ---------------------------------------------------------------------------

def test_oom_frontier_orders_like_paper():
    cap = RTX_4090.hbm_bytes
    qwen = max_seq_len(get("qwen2.5-0.5b"), cap)
    mamba = max_seq_len(get("mamba2-780m"), cap)
    falcon = max_seq_len(get("falcon-h1-0.5b"), cap)
    phi = max_seq_len(get("phi-3-mini"), cap)
    assert phi < qwen < falcon < mamba, (phi, qwen, falcon, mamba)
    assert mamba > 4 * qwen * 0.5, "SSM frontier should be ~4x transformer's"


def test_ssm_memory_flat_in_seq():
    m = get("mamba2-780m")
    a = inference_memory(m, 1, 8192).total
    b = inference_memory(m, 1, 65536).total
    # only activations grow (no KV cache): growth must be modest
    assert b < 2.5 * a


def test_kv_cache_matches_eq2():
    cfg = get("llama3-8b")
    from repro.core.memmodel import kv_cache_bytes
    b, s, p = 1, 4096, 2
    expected = b * s * cfg.n_layers * 2 * cfg.attn.n_kv_heads \
        * cfg.attn.head_dim * p
    assert kv_cache_bytes(cfg, b, s, p) == expected
