"""Measured profiler: op-name -> kernel-family mapping, the Chrome-trace
parser (on a canned fixture: container exclusion, host-thread filtering,
unknown-op residual), coarse-mode apportioning, and an end-to-end trace
window on this host's jax.
"""
import gzip
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.serving.profiler import (PROFILE_SCHEMA_VERSION, FamilyTimes,
                                    Profiler, family_map, parse_trace_dir,
                                    static_family_weights)


def _compiled():
    fn = jax.jit(lambda a, b: jnp.tanh(jnp.dot(a, b)))
    x = jnp.ones((64, 64), jnp.float32)
    return fn.lower(x, x).compile()


def test_family_map_covers_compiled_ops_with_operator_costs_taxonomy():
    fmap = family_map(_compiled().as_text())
    assert fmap                        # every op of every computation
    fams = set(fmap.values())
    assert "gemm" in fams              # the dot
    assert fams <= {"gemm", "ssm", "norm", "memory", "arith", "collective",
                    "other", "__container__"}
    weights = static_family_weights(_compiled().as_text())
    assert weights.get("gemm", 0) > 0.5
    assert sum(weights.values()) == pytest.approx(1.0)


# ---------------------------------------------------------- trace parser

def _trace_file(tmp_path, events):
    d = tmp_path / "plugins" / "profile" / "2026_01_01"
    d.mkdir(parents=True)
    with gzip.open(d / "host.trace.json.gz", "wt") as f:
        json.dump({"traceEvents": events}, f)
    return str(tmp_path)


def test_parse_trace_attributes_device_events_only(tmp_path):
    fmap = {"dot.1": "gemm", "tanh.2": "arith", "while.3": "__container__"}
    dev = {"pid": 1, "tid": 10}
    host = {"pid": 1, "tid": 99}
    events = [
        # device thread: known ops + one unknown + one container
        {"ph": "X", "name": "dot.1", "dur": 1000, **dev},
        {"ph": "X", "name": "dot.1", "dur": 500, **dev},
        {"ph": "X", "name": "tanh.2", "dur": 250, **dev},
        {"ph": "X", "name": "mystery.9", "dur": 100, **dev},
        # the while wraps the ops above: attributing it would double count
        {"ph": "X", "name": "while.3", "dur": 1850, **dev},
        # host python thread: never touched (no known op on that tid)
        {"ph": "X", "name": "PyCall", "dur": 99999, **host},
        # non-duration phases are skipped
        {"ph": "M", "name": "process_name", **dev},
    ]
    res = parse_trace_dir(_trace_file(tmp_path, events), fmap)
    assert res.ms["gemm"] == pytest.approx(1.5)       # 1500us -> ms
    assert res.ms["arith"] == pytest.approx(0.25)
    assert res.events == 3
    # unknown op ON a device thread -> unattributed; host events ignored
    assert res.unattributed_ms == pytest.approx(0.1)
    shares = res.shares()
    assert shares["gemm"] == pytest.approx(1.5 / 1.75)
    assert sum(shares.values()) == pytest.approx(1.0)


def test_parse_trace_empty_or_garbled_dir(tmp_path):
    assert parse_trace_dir(str(tmp_path), {"x": "gemm"}).events == 0
    bad = tmp_path / "a.trace.json.gz"
    bad.write_bytes(b"not gzip")
    assert parse_trace_dir(str(tmp_path), {"x": "gemm"}).events == 0


# ------------------------------------------------------------- modes

def test_off_mode_is_a_no_op():
    prof = Profiler(mode="off")
    assert not prof.enabled
    with prof.window("k") as ft:
        pass
    assert ft.ms == {} and ft.mode == "off"
    prof.observe("k", 5.0)
    snap = prof.snapshot()
    assert snap["coarse"] == {} and snap["windows"] == {}
    assert snap["version"] == PROFILE_SCHEMA_VERSION


def test_invalid_mode_rejected():
    with pytest.raises(ValueError, match="REPRO_PROFILE"):
        Profiler(mode="verbose")


def test_coarse_mode_apportions_by_static_weights():
    clock = iter([0.0, 0.010, 0.010, 0.010])    # 10ms window
    prof = Profiler(mode="coarse", clock=lambda: next(clock))
    prof.register("k", _compiled())
    assert prof.registered("k")
    with prof.window("k") as ft:
        pass
    assert ft.wall_ms == pytest.approx(10.0)
    assert ft.mode == "coarse"
    assert sum(ft.shares().values()) == pytest.approx(1.0)
    weights = static_family_weights(_compiled().as_text())
    for fam, w in weights.items():
        assert ft.ms[fam] == pytest.approx(10.0 * w)
    # unregistered keys leave the wall time unattributed, shares empty
    clock2 = iter([0.0, 0.004, 0.004, 0.004])
    prof2 = Profiler(mode="coarse", clock=lambda: next(clock2))
    with prof2.window("unknown") as ft2:
        pass
    assert ft2.shares() == {}
    assert ft2.unattributed_ms == pytest.approx(4.0)


def test_observe_accumulates_and_tracks_overhead():
    prof = Profiler(mode="coarse")
    prof.register("decode", _compiled())
    for _ in range(10):
        prof.observe("decode", 2.0)
    snap = prof.snapshot()
    rec = snap["coarse"]["decode"]
    assert rec["dispatches"] == 10
    assert rec["wall_ms"] == pytest.approx(20.0)
    assert sum(rec["shares"].values()) == pytest.approx(1.0)
    # bookkeeping self-time is measured and tiny vs the observed wall
    assert 0.0 <= prof.overhead_ms < 0.03 * 20.0


def test_trace_window_end_to_end_measures_gemm_dominance():
    """Real jax.profiler capture on this host: the dot-dominated program
    must attribute most device time to the gemm family; if the host
    yields no usable trace the window degrades (flagged) to static
    apportioning — either way shares exist and sum to 1."""
    prof = Profiler(mode="trace")
    fn = jax.jit(lambda a, b: jnp.tanh(jnp.dot(a, b)))
    x = jnp.ones((256, 256), jnp.float32)
    jax.block_until_ready(fn(x, x))                    # compile outside
    prof.register("k", fn.lower(x, x).compile())
    with prof.window("k") as ft:
        for _ in range(50):
            jax.block_until_ready(fn(x, x))
    shares = ft.shares()
    assert shares and sum(shares.values()) == pytest.approx(1.0)
    if not ft.degraded:
        assert ft.events > 0
        assert shares.get("gemm", 0) > 0.3
    snap = prof.snapshot()
    assert snap["windows"]["k"]["mode"] == "trace"
    assert snap["version"] == PROFILE_SCHEMA_VERSION


def test_family_times_merge():
    a = FamilyTimes(key="k", ms={"gemm": 1.0}, events=2, wall_ms=2.0)
    b = FamilyTimes(key="k", ms={"gemm": 1.0, "arith": 2.0},
                    unattributed_ms=0.5, events=3, wall_ms=3.0,
                    degraded=True)
    a.merge(b)
    assert a.ms == {"gemm": 2.0, "arith": 2.0}
    assert a.events == 5 and a.wall_ms == 5.0
    assert a.unattributed_ms == 0.5 and a.degraded
    d = a.as_dict()
    assert d["key"] == "k" and d["shares"]["arith"] == pytest.approx(0.5)
