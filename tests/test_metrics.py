"""Metrics registry: counter/gauge/histogram semantics, label children,
Prometheus text escaping, snapshot purity, and the export paths — plus
the engine integration (instruments actually move during a serving run).
"""
import json

import jax
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.metrics import (METRICS_SCHEMA_VERSION, Counter, Gauge,
                                   Histogram, MetricsRegistry)

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------- instruments

def test_counter_semantics():
    m = MetricsRegistry()
    c = m.counter("reqs_total", "requests")
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError, match=">= 0"):
        c.inc(-1)
    # labelled children are independent series; the default is untouched
    c.labels(status="ok").inc(4)
    c.labels(status="bad").inc()
    assert c.labels(status="ok").value == 4
    assert c.labels(status="bad").value == 1
    assert c.value == pytest.approx(3.5)
    # same label set -> same cached child
    assert c.labels(status="ok") is c.labels(status="ok")


def test_gauge_semantics():
    g = MetricsRegistry().gauge("queue_depth")
    g.set(7)
    g.inc()
    g.dec(3)
    assert g.value == 5.0
    g.set(-2.5)                     # gauges may go negative
    assert g.value == -2.5


def test_histogram_buckets_cumulative_with_inf_rail():
    h = MetricsRegistry().histogram("lat_ms", buckets=(1.0, 5.0, 10.0))
    for v in (0.2, 0.9, 3.0, 7.0, 100.0):
        h.observe(v)
    child = h.labels()
    assert child.count == 5
    assert child.sum == pytest.approx(111.1)
    cum = dict(child.cumulative())
    assert cum["1.0"] == 2          # 0.2, 0.9
    assert cum["5.0"] == 3          # + 3.0
    assert cum["10.0"] == 4         # + 7.0
    assert cum["+Inf"] == 5         # + 100.0 (over the last bound)
    with pytest.raises(ValueError, match=">= 1 bucket"):
        MetricsRegistry().histogram("empty", buckets=())


def test_get_or_create_and_type_conflicts():
    m = MetricsRegistry()
    a = m.counter("x_total", "first registration wins")
    b = m.counter("x_total", "ignored help")
    assert a is b and a.help == "first registration wins"
    assert isinstance(m.gauge("g"), Gauge)
    assert isinstance(m.histogram("h"), Histogram)
    with pytest.raises(ValueError, match="already registered as counter"):
        m.gauge("x_total")
    with pytest.raises(ValueError, match="already registered as gauge"):
        m.histogram("g")


# ------------------------------------------------------- snapshot/export

def test_snapshot_idempotent_and_pure():
    m = MetricsRegistry()
    m.counter("c_total").inc(3)
    m.gauge("g").set(1.5)
    m.histogram("h", buckets=(1.0,)).observe(0.5)
    s1 = m.snapshot()
    s2 = m.snapshot()
    assert s1 == s2
    assert s1["version"] == METRICS_SCHEMA_VERSION
    # mutating a snapshot never reaches the registry
    s1["metrics"]["c_total"]["samples"][0]["value"] = 999
    assert m.snapshot()["metrics"]["c_total"]["samples"][0]["value"] == 3
    # updates show up in the NEXT snapshot only
    m.counter("c_total").inc()
    assert m.snapshot()["metrics"]["c_total"]["samples"][0]["value"] == 4


def test_prometheus_text_format_and_escaping():
    m = MetricsRegistry()
    c = m.counter("req_total", 'help with "quotes" and \\slash\nline2')
    c.labels(name='va"l\\ue\nx').inc(2)
    m.histogram("lat_ms", "latency", buckets=(1.0,)).observe(0.5)
    text = m.to_prometheus()
    # HELP escapes backslash + newline, leaves quotes alone
    assert ('# HELP req_total help with "quotes" and '
            "\\\\slash\\nline2") in text
    assert "# TYPE req_total counter" in text
    # label VALUES escape backslash, quote and newline
    assert 'req_total{name="va\\"l\\\\ue\\nx"} 2' in text
    assert 'lat_ms_bucket{le="1.0"} 1' in text
    assert 'lat_ms_bucket{le="+Inf"} 1' in text
    assert "lat_ms_sum 0.5" in text
    assert "lat_ms_count 1" in text


def test_export_jsonl_appends_and_prom_overwrites(tmp_path):
    jsonl = str(tmp_path / "metrics.jsonl")
    t = iter([10.0, 20.0, 30.0])
    m = MetricsRegistry(clock=lambda: next(t), path=jsonl)
    m.counter("c_total").inc()
    assert m.export() == jsonl
    m.counter("c_total").inc()
    m.export()
    lines = [json.loads(x) for x in open(jsonl)]
    assert [ln["t"] for ln in lines] == [10.0, 20.0]
    assert [ln["version"] for ln in lines] == [METRICS_SCHEMA_VERSION] * 2
    assert lines[1]["metrics"]["c_total"]["samples"][0]["value"] == 2
    # .prom suffix switches to (overwritten) Prometheus text
    prom = str(tmp_path / "metrics.prom")
    m.export(prom)
    m.export(prom)
    text = open(prom).read()
    assert text.count("# TYPE c_total counter") == 1
    # export(None) falls back to the registry default path
    assert m.export(None) == jsonl
    assert MetricsRegistry(path=None).export() is None


# ------------------------------------------------------ engine threading

def _cfg():
    return ModelConfig(name="hyb", family="hybrid", n_layers=4, d_model=64,
                       d_ff=0, vocab_size=97,
                       ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                       layer_pattern=("mamba2", "mamba2+shared"),
                       shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                              head_dim=16),
                       shared_attn_d_ff=128, vocab_pad_multiple=16)


def test_engine_threads_metrics_through_serving_run(tmp_path):
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    path = str(tmp_path / "metrics.jsonl")
    eng = ServingEngine(cfg, params, slots=2, max_seq=96, decode_block=4,
                        chunk_size=16, checkpoint_every=4,
                        metrics=MetricsRegistry(path=path))
    rng = np.random.default_rng(0)
    for i, n in enumerate((20, 12)):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(2, 97, n).astype(np.int32),
                           max_new=12))
    done = eng.run(max_iters=500)
    assert all(r.status == "ok" for r in done)
    snap = eng.metrics.snapshot()["metrics"]

    def val(name, **labels):
        want = sorted((k, v) for k, v in labels.items())
        for s in snap[name]["samples"]:
            if sorted(s["labels"].items()) == want:
                return s.get("value", s.get("count"))
        return None

    assert val("repro_submitted_total") == 2
    assert val("repro_admitted_total") == 2
    assert val("repro_finished_total", status="ok") == 2
    assert val("repro_tokens_total", phase="decode") > 0
    assert val("repro_tokens_total", phase="prefill") == 32
    assert val("repro_checkpoints_total") > 0
    assert val("repro_checkpoint_bytes_total") > 0
    assert val("repro_offload_bytes_total") > 0
    assert snap["repro_decode_burst_ms"]["samples"][0]["count"] > 0
    assert snap["repro_prefill_chunk_ms"]["samples"][0]["count"] > 0
    assert val("repro_queue_depth") == 0        # drained at the end
    # run() flushed one JSONL line to REPRO_METRICS_PATH-equivalent
    lines = [json.loads(x) for x in open(path)]
    assert len(lines) == 1
    assert lines[0]["metrics"]["repro_finished_total"]["samples"]
