"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.kernels.conv1d.ref import causal_conv1d_ref
from repro.kernels.flash.ref import attention_ref
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_sequential
from repro.models import init_lm_params, lm_forward

SET = settings(max_examples=20, deadline=None)


@SET
@given(chunk=st.sampled_from([4, 8, 16, 32]),
       seed=st.integers(0, 2 ** 16))
def test_ssd_chunk_size_invariance(chunk, seed):
    """SSD output must not depend on the chunking (the dual form is exact)."""
    key = jax.random.PRNGKey(seed)
    b, s, h, p, g, n = 1, 32, 2, 4, 1, 4
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    D = jax.random.normal(ks[5], (h,))
    y_seq, h_seq = ssd_sequential(x, dt, A, Bm, Cm, D)
    y_c, h_c = ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_seq),
                               rtol=2e-3, atol=2e-3)


@SET
@given(split=st.integers(1, 63), seed=st.integers(0, 2 ** 16))
def test_conv1d_streaming_split_invariance(split, seed):
    """Streaming property: conv(x) == conv(x[:k]) ++ conv(x[k:], state)."""
    key = jax.random.PRNGKey(seed)
    b, s, c, k = 1, 64, 8, 4
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b, s, c))
    w = jax.random.normal(ks[1], (c, k))
    bias = jax.random.normal(ks[2], (c,))
    y_full, st_full = causal_conv1d_ref(x, w, bias)
    y1, st1 = causal_conv1d_ref(x[:, :split], w, bias)
    y2, st2 = causal_conv1d_ref(x[:, split:], w, bias, initial_state=st1)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-6)


@SET
@given(pos=st.integers(0, 14), seed=st.integers(0, 2 ** 16))
def test_attention_causality(pos, seed):
    """Perturbing token t must not change outputs at positions < t."""
    key = jax.random.PRNGKey(seed)
    b, h, kvh, s, d = 1, 4, 2, 16, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    o1 = attention_ref(q, k, v, causal=True)
    k2 = k.at[:, :, pos].add(1.0)
    v2 = v.at[:, :, pos].add(-2.0)
    o2 = attention_ref(q, k2, v2, causal=True)
    if pos > 0:
        np.testing.assert_allclose(np.asarray(o1[:, :, :pos]),
                                   np.asarray(o2[:, :, :pos]),
                                   rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(o1[:, :, pos:]),
                           np.asarray(o2[:, :, pos:]))


@SET
@given(window=st.integers(1, 8), seed=st.integers(0, 2 ** 10))
def test_sliding_window_locality(window, seed):
    """With window w, output at t only depends on tokens in (t-w, t]."""
    key = jax.random.PRNGKey(seed)
    b, h, kvh, s, d = 1, 2, 1, 16, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    o1 = attention_ref(q, k, v, causal=True, window=window)
    # perturb token 0: outputs at positions >= window must be unchanged
    k2 = k.at[:, :, 0].add(3.0)
    o2 = attention_ref(q, k2, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o1[:, :, window:]),
                               np.asarray(o2[:, :, window:]),
                               rtol=1e-5, atol=1e-5)


@SET
@given(seed=st.integers(0, 2 ** 16))
def test_lm_permutation_equivariance_over_batch(seed):
    """Permuting the batch permutes the logits (no cross-batch leakage)."""
    cfg = ModelConfig(name="t", family="ssm", n_layers=2, d_model=32, d_ff=0,
                      vocab_size=64,
                      ssm=SSMConfig(d_state=8, headdim=8, chunk=8),
                      layer_pattern=("mamba2",), vocab_pad_multiple=16)
    key = jax.random.PRNGKey(seed)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab_size, jnp.int32)
    out = lm_forward(cfg, params, {"tokens": tokens}, train=False)
    perm = jnp.array([2, 0, 3, 1])
    out_p = lm_forward(cfg, params, {"tokens": tokens[perm]}, train=False)
    np.testing.assert_allclose(np.asarray(out[perm], np.float32),
                               np.asarray(out_p, np.float32),
                               rtol=2e-2, atol=2e-2)
