"""Checkpointing: atomic roundtrip, retention, corruption detection, async."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore, save


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), t)
    for l1, l2 in zip(jax.tree_util.tree_leaves(t),
                      jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_retention(tmp_path):
    t = _tree()
    for s in range(6):
        save(str(tmp_path), s, t, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_corruption_detected(tmp_path):
    t = _tree()
    d = save(str(tmp_path), 1, t)
    npz = os.path.join(d, "arrays.npz")
    data = dict(np.load(npz))
    data["a"] = data["a"] + 1.0
    np.savez(npz, **data)
    with pytest.raises(IOError, match="corruption"):
        restore(str(tmp_path), t)


def test_structure_mismatch_rejected(tmp_path):
    save(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((4, 8)), "zz": jnp.zeros(3)}
    with pytest.raises(AssertionError, match="mismatch"):
        restore(str(tmp_path), bad)


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3):
        ck.save(s, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    out = restore(str(tmp_path), t, step=3)
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(t["b"]["c"]))


def test_elastic_reshard_roundtrip(tmp_path):
    """Restore with explicit shardings (single-device here, but exercises
    the device_put path used for mesh-to-mesh elasticity)."""
    t = _tree()
    save(str(tmp_path), 7, t)
    dev = jax.devices()[0]
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    out = restore(str(tmp_path), t, shardings=sh)
    assert out["a"].sharding == jax.sharding.SingleDeviceSharding(dev)
