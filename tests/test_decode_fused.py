"""Fused decode path: (1) ``decode_tokens`` must emit tokens identical to n
sequential ``lm_decode_step`` calls on every arch family, on both the ref
and interpret (Pallas) backends; (2) the fused decode-step kernels must
match their jnp oracle numerically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.kernels import dispatch
from repro.kernels.decode_fused.kernel import (mamba1_decode_fused_pallas,
                                               mamba2_decode_fused_pallas)
from repro.kernels.decode_fused.ref import (mamba1_decode_fused_ref,
                                            mamba2_decode_fused_ref)
from repro.models import (decode_tokens, init_lm_cache, init_lm_params,
                          lm_decode_step, lm_prefill)

KEY = jax.random.PRNGKey(0)


def _cfgs():
    return [
        ModelConfig(name="attn", family="dense", n_layers=3, d_model=64,
                    d_ff=128, vocab_size=97,
                    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
                    layer_pattern=("dense",), vocab_pad_multiple=16),
        ModelConfig(name="mamba2", family="ssm", n_layers=3, d_model=64,
                    d_ff=0, vocab_size=97,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                    layer_pattern=("mamba2",), vocab_pad_multiple=16),
        ModelConfig(name="mamba1", family="ssm", n_layers=2, d_model=64,
                    d_ff=0, vocab_size=97,
                    ssm=SSMConfig(d_state=8, variant="mamba1"),
                    layer_pattern=("mamba1",), vocab_pad_multiple=16),
        ModelConfig(name="hybrid", family="hybrid", n_layers=4, d_model=64,
                    d_ff=0, vocab_size=97,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                    layer_pattern=("mamba2", "mamba2+shared"),
                    shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                           head_dim=16),
                    shared_attn_d_ff=128, vocab_pad_multiple=16),
    ]


@pytest.mark.parametrize("backend", [
    "ref",
    # interpret sweep: hybrid exercises every fused kernel in one config;
    # the per-family interpret runs are the slow sweep (scripts/verify.sh)
    pytest.param("interpret", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("cfg", _cfgs(), ids=lambda c: c.name)
def test_decode_tokens_matches_sequential(cfg, backend):
    """The fused lax.scan loop must reproduce the per-token python loop
    exactly (same backend => identical op sequence => identical tokens)."""
    batch, plen, n = 2, 8, 6
    params = init_lm_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (batch, plen), 0, cfg.vocab_size,
                                jnp.int32)
    with dispatch.use_backend(backend):
        cache = init_lm_cache(cfg, batch, 32)
        lg, cache = jax.jit(lambda p, t, c: lm_prefill(
            cfg, p, {"tokens": t}, c))(params, prompt, cache)
        first = jnp.argmax(lg[..., :cfg.vocab_size], -1).astype(jnp.int32)

        seq_cache, tok, seq_toks = cache, first, []
        step = jax.jit(lambda p, t, c: lm_decode_step(cfg, p, t, c))
        for _ in range(n):
            lg1, seq_cache = step(params, tok, seq_cache)
            tok = jnp.argmax(lg1[..., :cfg.vocab_size], -1).astype(jnp.int32)
            seq_toks.append(np.asarray(tok[:, 0]))
        seq_toks = np.stack(seq_toks, axis=1)

        fused, fused_cache = jax.jit(
            lambda p, c, f: decode_tokens(cfg, p, c, f, n))(
                params, cache, first)
    np.testing.assert_array_equal(np.asarray(fused), seq_toks)
    np.testing.assert_array_equal(np.asarray(fused_cache["pos"]),
                                  np.asarray(seq_cache["pos"]))
    # states must agree too (bitwise on ref; kernels only reorder float ops)
    for a, b in zip(jax.tree_util.tree_leaves(fused_cache["segments"]),
                    jax.tree_util.tree_leaves(seq_cache["segments"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_decode_tokens_interpret_smoke():
    """Thin tier-1 interpret-parity smoke: the hybrid config alone touches
    every fused decode kernel (conv shift, SSM update, shared attention)."""
    test_decode_tokens_matches_sequential(_cfgs()[3], "interpret")


def test_decode_tokens_sampling_reproducible():
    cfg = _cfgs()[0]
    params = init_lm_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size, jnp.int32)
    cache = init_lm_cache(cfg, 2, 32)
    lg, cache = lm_prefill(cfg, params, {"tokens": prompt}, cache)
    first = jnp.argmax(lg[..., :cfg.vocab_size], -1).astype(jnp.int32)
    t1, _ = decode_tokens(cfg, params, cache, first, 8, temperature=0.8,
                          rng=jax.random.PRNGKey(7))
    t2, _ = decode_tokens(cfg, params, cache, first, 8, temperature=0.8,
                          rng=jax.random.PRNGKey(7))
    t3, _ = decode_tokens(cfg, params, cache, first, 8, temperature=0.8,
                          rng=jax.random.PRNGKey(8))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert (np.asarray(t1) < cfg.vocab_size).all()
    # a different key must actually change the sampled stream
    assert not np.array_equal(np.asarray(t1), np.asarray(t3))


# ------------------------------------------------------------ fused kernels

@pytest.mark.parametrize("b,h,p,g,n,k", [(2, 4, 16, 2, 16, 4),
                                         (1, 8, 8, 1, 32, 4),
                                         (3, 4, 32, 4, 8, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba2_decode_fused_kernel(b, h, p, g, n, k, dtype):
    di = h * p
    c = di + 2 * g * n
    ks = jax.random.split(KEY, 9)
    conv = jax.random.normal(ks[0], (b, k - 1, c), dtype)
    ssm = jax.random.normal(ks[1], (b, h, p, n), jnp.float32)
    xbc = jax.random.normal(ks[2], (b, c), dtype)
    w = jax.random.normal(ks[3], (c, k))
    bias = jax.random.normal(ks[4], (c,))
    dt_raw = jax.random.normal(ks[5], (b, h), dtype)
    dtb = jax.random.normal(ks[6], (h,))
    al = jax.random.normal(ks[7], (h,))
    D = jax.random.normal(ks[8], (h,))
    ref = mamba2_decode_fused_ref(conv, ssm, xbc, w, bias, dt_raw, dtb, al, D,
                                  n_groups=g, d_state=n, headdim=p)
    ker = mamba2_decode_fused_pallas(conv, ssm, xbc, w, bias, dt_raw, dtb,
                                     al, D, n_groups=g, d_state=n, headdim=p,
                                     interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    for r, got, nm in zip(ref, ker, ["y", "conv", "ssm"]):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=tol, atol=tol, err_msg=nm)


@pytest.mark.parametrize("b,di,n,dtr,k", [(2, 32, 8, 6, 4), (1, 64, 16, 4, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba1_decode_fused_kernel(b, di, n, dtr, k, dtype):
    ks = jax.random.split(KEY, 10)
    conv = jax.random.normal(ks[0], (b, k - 1, di), dtype)
    ssm = jax.random.normal(ks[1], (b, di, n), jnp.float32)
    xi = jax.random.normal(ks[2], (b, di), dtype)
    w = jax.random.normal(ks[3], (di, k))
    bias = jax.random.normal(ks[4], (di,))
    xp = jax.random.normal(ks[5], (di, dtr + 2 * n), dtype)
    dtp = jax.random.normal(ks[6], (dtr, di), dtype)
    dtb = jax.random.normal(ks[7], (di,))
    al = jax.random.normal(ks[8], (di, n))
    D = jax.random.normal(ks[9], (di,))
    ref = mamba1_decode_fused_ref(conv, ssm, xi, w, bias, xp, dtp, dtb, al, D,
                                  d_state=n, dt_rank=dtr)
    ker = mamba1_decode_fused_pallas(conv, ssm, xi, w, bias, xp, dtp, dtb,
                                     al, D, d_state=n, dt_rank=dtr,
                                     interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    for r, got, nm in zip(ref, ker, ["y", "conv", "ssm"]):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(r, np.float32),
                                   rtol=tol, atol=tol, err_msg=nm)
