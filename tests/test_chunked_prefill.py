"""Chunk-parity property: state-carrying chunked prefill must match the
one-shot ``lm_prefill`` — logits, cache positions, and the decode
continuation — for every architecture family, across chunk sizes
(including ragged last chunks), on the ref and Pallas-interpret backends,
and for heterogeneous prompt lengths in one padded batch.

Rolling sliding-window ("local") architectures go through the ring-buffer
chunk path: their parity sweep covers window == chunk, window < chunk
(wrap inside one chunk) and window > chunk, always with prompts longer
than the window so the ring cursor wraps.  Those configs pin
``compute_dtype=float32``: the ring and one-shot paths reduce in
different orders, and fp32 makes the bit-exact decode-continuation gate
deterministic instead of hostage to bf16 argmax near-ties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.kernels import dispatch
from repro.models.lm import (decode_tokens, init_lm_cache, init_lm_params,
                             lm_prefill, lm_prefill_chunk)
from repro.serving.prefill import chunked_prefill, supports_chunked_prefill

KEY = jax.random.PRNGKey(0)


def _cfgs():
    return {
        "dense": ModelConfig(
            name="dense", family="dense", n_layers=3, d_model=64, d_ff=128,
            vocab_size=97,
            attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
            layer_pattern=("dense",), vocab_pad_multiple=16),
        "mamba2": ModelConfig(
            name="mamba2", family="ssm", n_layers=3, d_model=64, d_ff=0,
            vocab_size=97, ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
            layer_pattern=("mamba2",), vocab_pad_multiple=16),
        "mamba1": ModelConfig(
            name="mamba1", family="ssm", n_layers=2, d_model=64, d_ff=0,
            vocab_size=97, ssm=SSMConfig(d_state=8, variant="mamba1"),
            layer_pattern=("mamba1",), vocab_pad_multiple=16),
        "hybrid": ModelConfig(
            name="hybrid", family="hybrid", n_layers=4, d_model=64, d_ff=0,
            vocab_size=97, ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
            layer_pattern=("mamba2", "mamba2+shared"),
            shared_attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
            shared_attn_d_ff=128, vocab_pad_multiple=16),
        "hybrid_par": ModelConfig(
            name="hybrid_par", family="hybrid", n_layers=2, d_model=64,
            d_ff=128, vocab_size=97,
            attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
            ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
            layer_pattern=("hybrid_par",), vocab_pad_multiple=16),
        # rolling sliding-window configs (ring-buffer chunked prefill);
        # fp32 compute — see module docstring
        "local": ModelConfig(
            name="local", family="dense", n_layers=2, d_model=64, d_ff=128,
            vocab_size=97, compute_dtype="float32",
            attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                            sliding_window=8),
            layer_pattern=("local", "dense"), vocab_pad_multiple=16),
        "local_pure": ModelConfig(
            name="local_pure", family="dense", n_layers=2, d_model=64,
            d_ff=128, vocab_size=97, compute_dtype="float32",
            attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                            sliding_window=8),
            layer_pattern=("local",), vocab_pad_multiple=16),
        "local_hybrid": ModelConfig(
            name="local_hybrid", family="hybrid", n_layers=2, d_model=64,
            d_ff=128, vocab_size=97, compute_dtype="float32",
            attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                            sliding_window=8),
            ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
            layer_pattern=("local", "mamba2"), vocab_pad_multiple=16),
    }


def _run_chunked(cfg, params, toks, max_seq, chunk):
    cache = init_lm_cache(cfg, toks.shape[0], max_seq)
    return chunked_prefill(cfg, params, toks, cache, chunk_size=chunk)


@pytest.mark.parametrize("arch", [
    "dense", "mamba2", "hybrid",                       # tier-1 smoke
    pytest.param("mamba1", marks=pytest.mark.slow),
    pytest.param("hybrid_par", marks=pytest.mark.slow),
])
@pytest.mark.parametrize("chunk", [
    7,                                                 # ragged — tier-1 smoke
    pytest.param(8, marks=pytest.mark.slow),           # even chunking
    pytest.param(21, marks=pytest.mark.slow),          # one-shot-sized
])
def test_chunk_parity(arch, chunk):
    """Chunked == one-shot: logits, pos, and an 8-token greedy
    continuation, for even and ragged chunkings (21 = one-shot-sized)."""
    cfg = _cfgs()[arch]
    assert supports_chunked_prefill(cfg)
    params = init_lm_params(cfg, KEY)
    B, L, MS = 2, 21, 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                              cfg.vocab_size, jnp.int32)
    ref_logits, ref_cache = lm_prefill(cfg, params, {"tokens": toks},
                                       init_lm_cache(cfg, B, MS))
    logits, cache = _run_chunked(cfg, params, toks, MS, chunk)
    # bf16 logits: tolerance must sit above bf16 ULP (2^-8) — a few-ULP
    # drift from reduction-order changes is expected; the bit-exact greedy
    # continuation below is the strong parity gate
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_array_equal(np.asarray(cache["pos"]),
                                  np.asarray(ref_cache["pos"]))
    first = jnp.argmax(ref_logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    t_ref, _ = decode_tokens(cfg, params, ref_cache, first, 8)
    t_chk, _ = decode_tokens(cfg, params, cache, first, 8)
    np.testing.assert_array_equal(np.asarray(t_chk), np.asarray(t_ref))


@pytest.mark.parametrize("arch,chunk", [
    ("local", 8),                                      # chunk == window
    ("local", 16),                                     # chunk > window: the
                                                       # ring wraps INSIDE one
                                                       # chunk
    ("local_pure", 5),                                 # chunk < window, ragged
    pytest.param("local", 5, marks=pytest.mark.slow),
    pytest.param("local_pure", 8, marks=pytest.mark.slow),
    pytest.param("local_pure", 16, marks=pytest.mark.slow),
    pytest.param("local_hybrid", 8, marks=pytest.mark.slow),
    pytest.param("local_hybrid", 5, marks=pytest.mark.slow),
])
def test_ring_chunk_parity(arch, chunk):
    """Ring-buffer chunked prefill == one-shot rolling prefill for
    window=8 configs with a 21-token prompt (the ring cursor wraps twice):
    logits, pos, the rolling-cache invariant (slot i holds the token with
    pos % window == i), and a bit-exact greedy continuation."""
    cfg = _cfgs()[arch]
    assert supports_chunked_prefill(cfg)
    params = init_lm_params(cfg, KEY)
    B, L, MS = 2, 21, 40
    window = cfg.attn.sliding_window
    assert L > window, "the test must wrap the ring cursor"
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                              cfg.vocab_size, jnp.int32)
    # fp32 caches as well as fp32 compute: the chunked path re-reads
    # earlier chunks' KV from the cache (one-shot never does), so a bf16
    # cache would inject quantization the reference path doesn't see
    ref_logits, ref_cache = lm_prefill(cfg, params, {"tokens": toks},
                                       init_lm_cache(cfg, B, MS,
                                                     dtype=jnp.float32))
    cache = init_lm_cache(cfg, B, MS, dtype=jnp.float32)
    logits, cache = chunked_prefill(cfg, params, toks, cache,
                                    chunk_size=chunk)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(cache["pos"]),
                                  np.asarray(ref_cache["pos"]))
    # the rolling invariant transfers: one-shot and ring paths must land
    # the same window contents in the same slots (a misaligned slot would
    # show up as an O(1) error, far above fp32 reduction drift)
    checked = 0
    for ref_leaf, leaf in zip(jax.tree_util.tree_leaves(ref_cache),
                              jax.tree_util.tree_leaves(cache)):
        if ref_leaf.ndim == 5 and ref_leaf.shape[2] == window:
            np.testing.assert_allclose(np.asarray(ref_leaf, np.float32),
                                       np.asarray(leaf, np.float32),
                                       rtol=1e-4, atol=1e-4)
            checked += 1
    assert checked >= 1
    first = jnp.argmax(ref_logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    t_ref, _ = decode_tokens(cfg, params, ref_cache, first, 8, rope_len=MS)
    t_chk, _ = decode_tokens(cfg, params, cache, first, 8, rope_len=MS)
    np.testing.assert_array_equal(np.asarray(t_chk), np.asarray(t_ref))


def test_ring_write_gated_by_lengths():
    """A zero-length (inert) row in a mixed group must leave its ring
    cache untouched even after the cursor has wrapped — an ungated write
    would clobber live window history that decode still attends."""
    cfg = _cfgs()["local_pure"]
    params = init_lm_params(cfg, KEY)
    B, MS, C = 2, 40, 8
    window = cfg.attn.sliding_window
    # row 0: prefill 2*window tokens so its ring is fully wrapped
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, 2 * window), 0,
                              cfg.vocab_size, jnp.int32)
    cache = init_lm_cache(cfg, B, MS)
    logits, cache = chunked_prefill(cfg, params, toks, cache, chunk_size=C)
    ring_before = [np.asarray(leaf)
                   for leaf in jax.tree_util.tree_leaves(cache)
                   if leaf.ndim == 5]
    # another chunk where BOTH rows are zero-length: pure no-op
    extra = jax.random.randint(jax.random.PRNGKey(5), (B, C), 0,
                               cfg.vocab_size, jnp.int32)
    _, cache2 = lm_prefill_chunk(cfg, params, {"tokens": extra}, cache,
                                 lengths=jnp.zeros((B,), jnp.int32))
    ring_after = [np.asarray(leaf)
                  for leaf in jax.tree_util.tree_leaves(cache2)
                  if leaf.ndim == 5]
    assert ring_before and len(ring_before) == len(ring_after)
    for a, b in zip(ring_before, ring_after):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(cache2["pos"]),
                                  np.asarray(cache["pos"]))


@pytest.mark.parametrize("arch", [
    "dense", "mamba2",                                 # tier-1 smoke: flash
                                                       # q_offset + scan/ssd
    "local",                                           # ring kv_wrap kernel
    pytest.param("mamba1", marks=pytest.mark.slow),
    pytest.param("hybrid", marks=pytest.mark.slow),
    pytest.param("local_pure", marks=pytest.mark.slow),
])
def test_chunk_parity_interpret_backend(arch):
    """The same parity through the Pallas kernels (interpret=True on CPU):
    exercises the flash q_offset path and initial-state scan/ssd/conv
    plumbing inside the compiled chunk step."""
    cfg = _cfgs()[arch]
    params = init_lm_params(cfg, KEY)
    B, L, MS = 2, 13, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                              cfg.vocab_size, jnp.int32)
    with dispatch.use_backend("interpret"):
        ref_logits, ref_cache = lm_prefill(cfg, params, {"tokens": toks},
                                           init_lm_cache(cfg, B, MS))
        logits, cache = _run_chunked(cfg, params, toks, MS, chunk=5)
    # bf16 logits: tolerance must sit above bf16 ULP (2^-8) — a few-ULP
    # drift from reduction-order changes is expected
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_array_equal(np.asarray(cache["pos"]),
                                  np.asarray(ref_cache["pos"]))


@pytest.mark.parametrize("arch", [
    "dense",                                           # tier-1 smoke
    pytest.param("hybrid", marks=pytest.mark.slow),
    pytest.param("mamba1", marks=pytest.mark.slow),
    pytest.param("mamba2", marks=pytest.mark.slow),
])
def test_mixed_length_batch_matches_solo(arch):
    """One padded heterogeneous batch (no same-length grouping): every
    row's logits and cache states must equal a batch-1 prefill of just
    that row's prompt."""
    cfg = _cfgs()[arch]
    params = init_lm_params(cfg, KEY)
    MS = 40
    rng = np.random.default_rng(0)
    lens = [5, 17, 9]
    prompts = [rng.integers(2, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    padded = np.zeros((len(lens), max(lens)), np.int32)
    for i, p in enumerate(prompts):
        padded[i, :len(p)] = p
    cache = init_lm_cache(cfg, len(lens), MS)
    logits, cache = chunked_prefill(cfg, params, jnp.asarray(padded), cache,
                                    chunk_size=6, lengths=lens)
    assert np.asarray(cache["pos"]).tolist() == lens
    for i, p in enumerate(prompts):
        solo_logits, solo_cache = lm_prefill(
            cfg, params, {"tokens": jnp.asarray(p[None])},
            init_lm_cache(cfg, 1, MS))
        np.testing.assert_allclose(np.asarray(logits[i], np.float32),
                                   np.asarray(solo_logits[0], np.float32),
                                   rtol=2e-2, atol=2e-2)
        # decode continuation must agree token-for-token with the solo row
        first = jnp.argmax(solo_logits[..., :cfg.vocab_size],
                           -1).astype(jnp.int32)
        t_solo, _ = decode_tokens(cfg, params, solo_cache, first, 6)
        from repro.serving.cache import extract_slot
        row = extract_slot(cache, i)
        t_row, _ = decode_tokens(cfg, params, row, first, 6)
        np.testing.assert_array_equal(np.asarray(t_row), np.asarray(t_solo))


def test_zero_length_rows_are_inert():
    """Rows admitted with length 0 (batch padding in the serving group)
    must leave their carried state untouched: conv/SSM states stay zero
    and pos stays put.  (Their KV rows may receive scratch writes — those
    are hidden by the decode-time valid_len mask and later overwrites.)"""
    cfg = _cfgs()["hybrid"]
    params = init_lm_params(cfg, KEY)
    B, MS, C = 2, 24, 8
    cache = init_lm_cache(cfg, B, MS)
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, C), 0,
                              cfg.vocab_size, jnp.int32)
    lens = jnp.asarray([C, 0], jnp.int32)
    _, new_cache = jax.jit(
        lambda p, t, l, c: lm_prefill_chunk(cfg, p, {"tokens": t}, c,
                                            lengths=l)
    )(params, toks, lens, cache)
    assert np.asarray(new_cache["pos"]).tolist() == [C, 0]
    checked = 0
    for seg in new_cache["segments"]:
        for layer in seg:
            for key in ("conv", "ssm"):
                if key in layer:
                    # leaves are [n_rep, B, ...]; row 1 was inert (dt is
                    # driven through softplus(-30) ~ 1e-13, not exactly 0)
                    row = np.asarray(layer[key][:, 1], np.float32)
                    np.testing.assert_allclose(row, np.zeros_like(row),
                                               atol=1e-9)
                    checked += 1
    assert checked >= 2


def test_supports_chunked_prefill_exclusions():
    """Every decodable architecture chunks — rolling windows included
    (ring-buffer path).  Only encoders (no prefix-extension recurrence)
    and audio frontends (feature inputs, not tokens) are excluded."""
    cfgs = _cfgs()
    assert supports_chunked_prefill(cfgs["dense"])
    assert supports_chunked_prefill(cfgs["local"])
    assert supports_chunked_prefill(cfgs["local_pure"])
    assert supports_chunked_prefill(cfgs["local_hybrid"])
    enc = ModelConfig(
        name="enc", family="encoder", n_layers=2, d_model=64, d_ff=128,
        vocab_size=97,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16, causal=False),
        layer_pattern=("encoder",), vocab_pad_multiple=16)
    assert not supports_chunked_prefill(enc)
    audio = ModelConfig(
        name="aud", family="audio", n_layers=2, d_model=64, d_ff=128,
        vocab_size=97,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        layer_pattern=("dense",), frontend="audio",
        frontend_feature_dim=16, vocab_pad_multiple=16)
    assert not supports_chunked_prefill(audio)
