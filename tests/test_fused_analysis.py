"""Fused-region analysis + in-place byte-accounting unit tests."""
import jax
import jax.numpy as jnp

from repro.core.hlo_analysis import HloAnalyzer


def _analyzer(f, *specs):
    txt = jax.jit(f).lower(*specs).compile().as_text()
    return HloAnalyzer(txt)


def test_fused_region_bytes_io_only():
    """A scoped elementwise chain fuses to one kernel: io bytes only."""
    D = 512
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def f(x):
        with jax.named_scope("ssd_core"):
            y = jnp.exp(x)
            y = y * 2.0
            y = jnp.tanh(y)
            y = y + 1.0
        return y

    an = _analyzer(f, x)
    eager = an.summarize()
    fused = an.summarize_fused()
    assert fused.bytes <= eager.bytes + 1
    # io = read + write = 2 * D*D*4 (+ small constants)
    assert fused.by_class()["ssm"]["bytes"] <= 2.5 * D * D * 4


def test_super_region_merges_conv_and_scan():
    D = 256
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def f(x):
        with jax.named_scope("conv1d"):
            y = jnp.exp(x) * 0.5
        with jax.named_scope("ssd_core"):
            z = jnp.tanh(y) + 1.0
        return z

    an = _analyzer(f, x)
    fused = an.summarize_fused()
    names = {k.name for k in fused.kernels if k.opcode == "fused-region"}
    assert names == {"fused_ssm_combined"}, names
    # the y intermediate between conv and scan is interior: <= in + out
    ssm = fused.by_class()["ssm"]
    assert ssm["bytes"] <= 2.5 * D * D * 4


def test_dus_charged_update_only():
    big = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 4096), jnp.float32)

    def f(b, u):
        return jax.lax.dynamic_update_slice(b, u, (3, 0))

    # donate the buffer so XLA aliases in place (as cache updates do)
    txt = jax.jit(f, donate_argnums=(0,)).lower(big, upd).compile().as_text()
    s = HloAnalyzer(txt).summarize()
    # in-place: ~2x the update slice, NOT the 67MB buffer
    assert s.bytes < 10 * 4096 * 4, s.bytes


def test_sliced_fusion_operand_charged_slice():
    big = jax.ShapeDtypeStruct((8192, 1024), jnp.float32)
    idx = jax.ShapeDtypeStruct((), jnp.int32)

    def f(b, i):
        row = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0)
        return jnp.tanh(row) * 2.0

    an = _analyzer(f, big, idx)
    s = an.summarize()
    assert s.bytes < 50 * 1024 * 4, s.bytes   # not the 33MB buffer
