"""Mamba-1 selective-scan Pallas kernel: shape/dtype sweep vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.scan1.kernel import selective_scan_pallas
from repro.kernels.scan1.ref import selective_scan_ref
from repro.models.mamba1 import selective_scan as assoc_scan

KEY = jax.random.PRNGKey(0)


def _data(b, s, c, n, dtype):
    ks = jax.random.split(KEY, 7)
    return (jax.random.normal(ks[0], (b, s, c), dtype),
            jax.nn.softplus(jax.random.normal(ks[1], (b, s, c))).astype(
                jnp.float32),
            -jnp.exp(jax.random.normal(ks[2], (c, n))),
            jax.random.normal(ks[3], (b, s, n), dtype),
            jax.random.normal(ks[4], (b, s, n), dtype),
            jax.random.normal(ks[5], (c,)),
            jax.random.normal(ks[6], (b, c, n), jnp.float32))


@pytest.mark.parametrize("b,s,c,n,bs,bc", [
    (1, 32, 16, 8, 8, 16), (2, 64, 32, 16, 16, 16),
    pytest.param(1, 48, 64, 16, 16, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan1_kernel_sweep(b, s, c, n, bs, bc, dtype):
    x, dt, A, Bm, Cm, D, h0 = _data(b, s, c, n, dtype)
    y1, h1 = selective_scan_ref(x, dt, A, Bm, Cm, D, initial_state=h0)
    y2, h2 = selective_scan_pallas(x, dt, A, Bm, Cm, D, initial_state=h0,
                                   block_seq=bs, block_ch=bc, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    scale = float(jnp.abs(y1.astype(jnp.float32)).max()) + 1e-6
    assert float(jnp.abs(y1.astype(jnp.float32)
                         - y2.astype(jnp.float32)).max()) / scale < tol
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1),
                               rtol=1e-3, atol=1e-3)


def test_scan1_all_three_paths_agree():
    x, dt, A, Bm, Cm, D, h0 = _data(2, 64, 32, 16, jnp.float32)
    y1, h1 = selective_scan_ref(x, dt, A, Bm, Cm, D, initial_state=h0)
    y2, h2 = selective_scan_pallas(x, dt, A, Bm, Cm, D, initial_state=h0,
                                   block_seq=16, block_ch=16, interpret=True)
    y3, h3 = assoc_scan(x, dt, A, Bm, Cm, D, initial_state=h0, chunk=16)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h3),
                               rtol=1e-4, atol=1e-4)
