"""GShard (einsum/capacity) vs ragged (sort-based) MoE equivalence: with
capacity ample enough that nothing drops, both dispatch paths must produce
the same output."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import MoEConfig
from repro.models.moe import moe_gshard, moe_param_defs, moe_ragged
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


def _setup(shared=False):
    m = MoEConfig(n_experts=8, experts_per_token=2, d_ff_expert=32,
                  capacity_factor=8.0, shared_expert=shared)
    d = 16
    p = init_params(moe_param_defs(d, m), KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d), jnp.float32)
    return m, p, x


def test_gshard_matches_ragged_no_drop():
    m, p, x = _setup()
    y1 = moe_gshard(p, x, m, n_groups=1)
    y2 = moe_ragged(p, x, m)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)


def test_gshard_group_count_invariance():
    m, p, x = _setup()
    y1 = moe_gshard(p, x, m, n_groups=1)
    y2 = moe_gshard(p, x, m, n_groups=4)
    # different grouping = different capacity pools; with cf=8 nothing
    # drops, so outputs agree
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)


def test_shared_expert_always_on():
    m, p, x = _setup(shared=True)
    y = moe_gshard(p, x, m, n_groups=1)
    # zero out routed experts: shared expert contribution must remain
    p0 = dict(p)
    for k in ("wi", "wg", "wo"):
        p0[k] = jnp.zeros_like(p[k])
    y0 = moe_gshard(p0, x, m, n_groups=1)
    assert float(jnp.abs(y0).max()) > 0.0
    assert not np.allclose(np.asarray(y), np.asarray(y0))
