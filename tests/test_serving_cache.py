"""Serving cache administration: slot extract/insert/offload roundtrip +
admission sizing, the durable-store blob container round-trip
(serialize -> deserialize -> validate -> restore, with every single-byte
payload mutation and tag-field mutation rejected), and the pinned
legacy tag-less-blob compatibility path."""
import json

import jax
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_cache
from repro.serving.cache import (BLOB_META_KEY, blob_tags, cache_bytes,
                                 extract_slot, insert_slot, max_slots,
                                 offload_slot, restore_slot, slot_schema,
                                 validate_blob)
from repro.serving.faults import CacheCorruption
from repro.serving.store import BLOB_MAGIC, dump_blob, parse_blob


def _cfg():
    return ModelConfig(
        name="c", family="hybrid", n_layers=4, d_model=64, d_ff=0,
        vocab_size=64, ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
        shared_attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        shared_attn_d_ff=128, layer_pattern=("mamba2", "mamba2+shared"),
        vocab_pad_multiple=16)


def test_slot_roundtrip():
    cfg = _cfg()
    cache = init_lm_cache(cfg, 3, 32)
    # fill with recognizable values
    cache = jax.tree_util.tree_map(
        lambda x: (jax.numpy.ones_like(x) * 7 if x.ndim else x), cache)
    one = extract_slot(cache, 1)
    for leaf in jax.tree_util.tree_leaves(one):
        if leaf.ndim > 1:            # segment leaves: [n_rep, B, ...]
            assert leaf.shape[1] == 1
        elif leaf.ndim:              # pos vector: [B]
            assert leaf.shape[0] == 1
    blob = offload_slot(cache, 1)
    fresh = init_lm_cache(cfg, 3, 32)
    fresh = restore_slot(fresh, blob, 2)
    got = extract_slot(fresh, 2)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(one)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_admission_sizing():
    cfg = _cfg()
    per = cache_bytes(cfg, 1, 2048)
    assert per > 0
    n = max_slots(cfg, 2048, hbm_budget=100 * per + 5e6, weight_bytes=5e6)
    assert n == 100
    assert max_slots(cfg, 2048, hbm_budget=1e3, weight_bytes=5e6) == 0


# ----------------------------------------------------- durable container
def _filled_cache(pos=5):
    """A batch-3 hybrid cache with recognizable payload and a nonzero
    live prefix (so attention-KV live-bounded crcs cover real bytes)."""
    cache = init_lm_cache(_cfg(), 3, 32)
    cache = jax.tree_util.tree_map(
        lambda x: (jax.numpy.ones_like(x) * 7 if x.ndim else x), cache)
    return dict(cache, pos=jax.numpy.full((3,), pos, jax.numpy.int32))


def _payload_offsets(data: bytes):
    """(payload_start, {key: (offset, nbytes)}) of a serialized blob."""
    hlen = int.from_bytes(data[len(BLOB_MAGIC):len(BLOB_MAGIC) + 8],
                          "little")
    start = len(BLOB_MAGIC) + 8 + hlen
    header = json.loads(data[len(BLOB_MAGIC) + 8:start])
    return start, {k: (d["offset"], d["nbytes"])
                   for k, d in header["arrays"].items()}


def test_store_container_roundtrip_restores_bit_exact():
    cache = _filled_cache()
    blob = offload_slot(cache, 1, tags={"rid": 7, "priority": 2})
    back = parse_blob(dump_blob(blob))
    assert back[BLOB_META_KEY] == blob[BLOB_META_KEY]
    assert blob_tags(back) == {"rid": 7, "priority": 2}
    keys = [k for k in blob if k != BLOB_META_KEY]
    validate_blob(back, keys)
    fresh = init_lm_cache(_cfg(), 3, 32)
    fresh = restore_slot(fresh, back, 2, expect_tags={"rid": 7})
    got = extract_slot(fresh, 2)
    want = extract_slot(cache, 1)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_store_container_rejects_any_first_byte_flip():
    """Deterministic sweep: flipping the FIRST payload byte of every
    array region (always inside the live-crc-covered prefix) must fail
    validation naming a key — no key's region is silently mutable."""
    cache = _filled_cache()
    blob = offload_slot(cache, 0, tags={"rid": 3})
    data = dump_blob(blob)
    start, regions = _payload_offsets(data)
    keys = [k for k in blob if k != BLOB_META_KEY]
    for k, (off, nbytes) in regions.items():
        if nbytes == 0:
            continue
        damaged = bytearray(data)
        damaged[start + off] ^= 0x01
        with pytest.raises(CacheCorruption):
            validate_blob(parse_blob(bytes(damaged)), keys)


def test_store_container_rejects_truncation():
    blob = offload_slot(_filled_cache(), 0)
    data = dump_blob(blob)
    for cut in (len(data) - 1, len(data) // 2, len(BLOB_MAGIC) + 4, 3):
        with pytest.raises(CacheCorruption):
            parse_blob(data[:cut])


def test_store_container_rejects_tag_mutation():
    """A mutated identity tag must be refused at restore even though
    every payload crc still passes (the blob is honest about its bytes,
    dishonest about whose bytes they are)."""
    cache = _filled_cache()
    blob = offload_slot(cache, 0, tags={"rid": 7})
    back = parse_blob(dump_blob(blob))
    meta = json.loads(back[BLOB_META_KEY])
    meta["tags"]["rid"] = 8
    back[BLOB_META_KEY] = json.dumps(meta)
    fresh = init_lm_cache(_cfg(), 3, 32)
    with pytest.raises(CacheCorruption):
        restore_slot(fresh, back, 0, expect_tags={"rid": 7})


def test_legacy_tagless_blob_compat_pinned():
    """REGRESSION PIN: meta-less blobs (written before the ``__meta__``
    integrity record existed) must keep passing the key-set-only path in
    ``validate_blob`` AND restore under ``expect_tags`` (no tags = no
    mismatch) — a future tag-schema bump must not silently drop this."""
    cache = _filled_cache()
    blob = offload_slot(cache, 1)
    legacy = {k: v for k, v in blob.items() if k != BLOB_META_KEY}
    keys = list(legacy)
    validate_blob(legacy, keys)                       # key-set check only
    assert blob_tags(legacy) == {}
    fresh = init_lm_cache(_cfg(), 3, 32)
    fresh = restore_slot(fresh, legacy, 0, expect_tags={"rid": 42})
    got = extract_slot(fresh, 0)
    want = extract_slot(cache, 1)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a legacy blob survives the durable container meta-less
    back = parse_blob(dump_blob(legacy))
    assert BLOB_META_KEY not in back
    validate_blob(back, keys)
    # and the key-set diff still rejects structural damage
    short = dict(legacy)
    short.pop(keys[0])
    with pytest.raises(CacheCorruption):
        validate_blob(short, keys)


def test_slot_schema_matches_offload():
    cache = init_lm_cache(_cfg(), 3, 32)
    blob = offload_slot(cache, 0)
    want = {k: [list(v.shape), str(v.dtype)]
            for k, v in blob.items() if k != BLOB_META_KEY}
    assert slot_schema(cache) == want


def test_blob_roundtrip_property():
    """Hypothesis sweep (skipped where hypothesis is absent): arbitrary
    dtypes/shapes/pos through offload_slot -> serialize -> deserialize ->
    validate_blob -> restore_slot round-trip bit-exactly, and ANY single
    mutated payload byte or tag field is rejected."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    dtypes = st.sampled_from(["float32", "float16", "int32", "int8"])
    shapes = st.lists(st.integers(1, 4), min_size=0, max_size=2)

    @settings(max_examples=20, deadline=None)
    @given(data=st.data())
    def prop(data):
        batch = 2
        n_leaves = data.draw(st.integers(1, 3), label="n_leaves")
        seg = {}
        for i in range(n_leaves):
            n_rep = data.draw(st.integers(1, 2), label=f"n_rep{i}")
            dims = tuple(data.draw(shapes, label=f"dims{i}"))
            dt = data.draw(dtypes, label=f"dtype{i}")
            shape = (n_rep, batch) + dims
            n = int(np.prod(shape))
            arr = (np.arange(1, n + 1) % 120 + 1).reshape(shape)
            seg[f"leaf{i}"] = jax.numpy.asarray(arr.astype(dt))
        pos = data.draw(st.integers(0, 7), label="pos")
        cache = {"segments": [seg],
                 "pos": jax.numpy.full((batch,), pos, jax.numpy.int32)}
        rid = data.draw(st.integers(0, 99), label="rid")
        blob = offload_slot(cache, 1, tags={"rid": rid})
        wire = dump_blob(blob)
        back = parse_blob(wire)
        keys = [k for k in blob if k != BLOB_META_KEY]
        validate_blob(back, keys)
        zero = jax.tree_util.tree_map(jax.numpy.zeros_like, cache)
        restored = restore_slot(zero, back, 0, expect_tags={"rid": rid})
        got = extract_slot(restored, 0)
        want = extract_slot(cache, 1)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # any single mutated payload byte is rejected
        start, _ = _payload_offsets(wire)
        if len(wire) > start:
            byte = data.draw(st.integers(0, len(wire) - start - 1),
                             label="flip_byte")
            bit = data.draw(st.integers(0, 7), label="flip_bit")
            damaged = bytearray(wire)
            damaged[start + byte] ^= (1 << bit)
            with pytest.raises(CacheCorruption):
                validate_blob(parse_blob(bytes(damaged)), keys)
        # any mutated tag field is rejected at restore
        tampered = dict(back)
        meta = json.loads(tampered[BLOB_META_KEY])
        meta["tags"]["rid"] = rid + 1
        tampered[BLOB_META_KEY] = json.dumps(meta)
        with pytest.raises(CacheCorruption):
            restore_slot(zero, tampered, 0, expect_tags={"rid": rid})
        # any truncation is rejected
        cut = data.draw(st.integers(0, len(wire) - 1), label="cut")
        with pytest.raises(CacheCorruption):
            validate_blob(parse_blob(wire[:cut]), keys)

    prop()
