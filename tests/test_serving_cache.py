"""Serving cache administration: slot extract/insert/offload roundtrip +
admission sizing."""
import jax
import numpy as np

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_cache
from repro.serving.cache import (cache_bytes, extract_slot, insert_slot,
                                 max_slots, offload_slot, restore_slot)


def _cfg():
    return ModelConfig(
        name="c", family="hybrid", n_layers=4, d_model=64, d_ff=0,
        vocab_size=64, ssm=SSMConfig(d_state=16, headdim=16, chunk=16),
        shared_attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        shared_attn_d_ff=128, layer_pattern=("mamba2", "mamba2+shared"),
        vocab_pad_multiple=16)


def test_slot_roundtrip():
    cfg = _cfg()
    cache = init_lm_cache(cfg, 3, 32)
    # fill with recognizable values
    cache = jax.tree_util.tree_map(
        lambda x: (jax.numpy.ones_like(x) * 7 if x.ndim else x), cache)
    one = extract_slot(cache, 1)
    for leaf in jax.tree_util.tree_leaves(one):
        if leaf.ndim > 1:            # segment leaves: [n_rep, B, ...]
            assert leaf.shape[1] == 1
        elif leaf.ndim:              # pos vector: [B]
            assert leaf.shape[0] == 1
    blob = offload_slot(cache, 1)
    fresh = init_lm_cache(cfg, 3, 32)
    fresh = restore_slot(fresh, blob, 2)
    got = extract_slot(fresh, 2)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(one)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_admission_sizing():
    cfg = _cfg()
    per = cache_bytes(cfg, 1, 2048)
    assert per > 0
    n = max_slots(cfg, 2048, hbm_budget=100 * per + 5e6, weight_bytes=5e6)
    assert n == 100
    assert max_slots(cfg, 2048, hbm_budget=1e3, weight_bytes=5e6) == 0
