"""Scheduling policy layer: unit coverage for the pure policy decisions
in ``src/repro/serving/scheduler.py`` (ordering, DRR credit accounting,
starvation bounds, victim selection, config parsing) plus engine-level
invariants — strict-tier preemption restores bit-exactly across KV
bucket rungs, the weighted_fair aging bound beats sustained high-class
load, strict_tiers converts unbounded waiting into ``StarvationTimeout``,
and the tentpole invariant: per-request decoded outputs are
bit-identical under every policy (policies reorder work, never math).
The slow sweep runs the bit-identity check across dense/mamba2/hybrid
x ref/interpret backends."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.kernels import dispatch
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import StarvationTimeout
from repro.serving.scheduler import (POLICIES, Scheduler,
                                     StrictTiersScheduler, VictimCandidate,
                                     WeightedFairScheduler, make_scheduler,
                                     parse_weights)
from tests.test_faults import FakeClock, _prompts, _setup


def _req(priority=0, submit_t=0.0, deadline_ms=None, rid=0):
    return SimpleNamespace(priority=priority, submit_t=submit_t,
                           deadline_ms=deadline_ms, rid=rid)


# ------------------------------------------------------------ config parsing

def test_parse_weights():
    assert parse_weights(None) == {}
    assert parse_weights("") == {}
    assert parse_weights("0:1,1:4") == {0: 1.0, 1: 4.0}
    assert parse_weights(" 0:1 , 2:16.5 ,") == {0: 1.0, 2: 16.5}


@pytest.mark.parametrize("bad", ["1", "a:2", "1:x", "-1:2", "1:0", "1:-3"])
def test_parse_weights_rejects(bad):
    with pytest.raises(ValueError, match="malformed"):
        parse_weights(bad)


def test_make_scheduler(monkeypatch):
    assert make_scheduler().policy == "fifo"
    assert make_scheduler("weighted_fair", {1: 4.0}).weights == {1: 4.0}
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_scheduler("lottery")
    monkeypatch.setenv("REPRO_SCHED_POLICY", "strict_tiers")
    monkeypatch.setenv("REPRO_SCHED_WEIGHTS", "0:1,1:8")
    s = make_scheduler()
    assert s.policy == "strict_tiers" and s.weights == {0: 1.0, 1: 8.0}
    # explicit arguments beat the environment
    assert make_scheduler("fifo", {}).policy == "fifo"
    monkeypatch.setenv("REPRO_SCHED_POLICY", "casino")
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_scheduler()


# ------------------------------------------------------------------- fifo

def test_fifo_defaults():
    s = Scheduler()
    q = [_req(rid=i, priority=p) for i, p in enumerate((0, 3, 1))]
    assert s.admission_order(q, now=1.0) == q          # submit order
    assert s.starved_out(q, [], now=1e9) == []         # never starves
    assert not s.urgent_preempt(q, [_req()])
    assert s.interleave_share([0], [3]) == 1.0
    assert s.expired(_req(submit_t=0.0, deadline_ms=50.0), now=0.06)
    assert not s.expired(_req(submit_t=0.0, deadline_ms=50.0), now=0.04)
    assert not s.expired(_req(deadline_ms=None), now=1e9)


def test_fifo_victim_most_slack_then_most_remaining():
    s = Scheduler()
    cands = [VictimCandidate(slot=0, priority=0, slack=10.0, remaining=64),
             VictimCandidate(slot=1, priority=0, slack=90.0, remaining=4),
             VictimCandidate(slot=2, priority=0, slack=90.0, remaining=32)]
    assert s.preempt_victim(cands, []) == 2            # slack tie -> work
    inf = VictimCandidate(slot=3, priority=5, slack=float("inf"),
                          remaining=1)
    assert s.preempt_victim(cands + [inf], []) == 3    # deadline-less first
    assert s.preempt_victim([], []) is None


def test_class_service_accumulates():
    s = Scheduler()
    s.note_service(0, 10)
    s.note_service(1, 4)
    s.note_service(0, 6)
    s.note_service(1, 0)                               # no-op
    assert s.class_service() == {0: 16.0, 1: 4.0}


# ----------------------------------------------------------- strict tiers

def test_strict_tiers_order_stable_within_class():
    s = StrictTiersScheduler()
    a, b, c, d = (_req(rid=i, priority=p)
                  for i, p in enumerate((0, 2, 1, 2)))
    assert s.admission_order([a, b, c, d], now=0.0) == [b, d, c, a]


def test_strict_tiers_urgent_preempt_and_victim():
    s = StrictTiersScheduler()
    live = [_req(priority=0), None]
    assert s.urgent_preempt([_req(priority=1)], live)
    assert not s.urgent_preempt([_req(priority=0)], live)
    assert not s.urgent_preempt([], live)
    cands = [VictimCandidate(slot=0, priority=0, slack=5.0, remaining=8),
             VictimCandidate(slot=1, priority=2, slack=99.0, remaining=99)]
    # evicts the LOWEST class even when a higher-class slot has more slack
    assert s.preempt_victim(cands, [_req(priority=1)]) == 0
    # never evicts for an equal-or-lower class
    assert s.preempt_victim(cands, [_req(priority=0)]) is None


def test_strict_tiers_starves_only_outranked_waiters():
    s = StrictTiersScheduler(starve_ms=100.0)
    low = _req(priority=0, submit_t=0.0)
    peer = _req(priority=1, submit_t=0.0)
    high = _req(priority=1, submit_t=0.35)
    assert s.starved_out([low, high], [], now=0.4) == [low]
    # the top class itself never times out, however long it waited
    assert s.starved_out([peer, high], [], now=0.4) == []
    # live slots count toward the outranking class too
    assert s.starved_out([low], [_req(priority=1)], now=0.4) == [low]
    assert StrictTiersScheduler(starve_ms=None).starved_out(
        [low], [], now=1e9) == []


def test_strict_tiers_interleave_yields_for_higher_class_decode():
    s = StrictTiersScheduler()
    assert s.interleave_share([0], [1]) == 0.5
    assert s.interleave_share([1], [0]) == 1.0
    assert s.interleave_share([1], [1]) == 1.0
    assert s.interleave_share([], [1]) == 1.0


# ---------------------------------------------------------- weighted fair

def test_drr_round_fires_only_on_exhaustion():
    s = WeightedFairScheduler(weights={0: 1.0, 1: 4.0}, quantum=8)
    q = [_req(rid=0, priority=0), _req(rid=1, priority=1)]
    order = s.admission_order(q, now=0.0)
    # first round banks quantum x weight -> class 1 outranks class 0
    assert [r.priority for r in order] == [1, 0]
    assert s._credit == {0: 8.0, 1: 32.0}
    # no exhaustion -> repeated calls must NOT bank more credit
    s.admission_order(q, now=0.0)
    assert s._credit == {0: 8.0, 1: 32.0}
    # service debits; once ALL queued classes are exhausted a new round
    # fires on top of the residual deficit (classic DRR)
    s.note_service(1, 32)
    s.admission_order(q, now=0.0)
    assert s._credit == {0: 8.0, 1: 0.0}
    s.note_service(0, 10)
    s.admission_order(q, now=0.0)
    assert s._credit == {0: 6.0, 1: 32.0}


def test_drr_sustained_backlog_converges_to_weights():
    s = WeightedFairScheduler(weights={0: 1.0, 1: 4.0}, quantum=8)
    q = [_req(rid=0, priority=0), _req(rid=1, priority=1)]
    for _ in range(400):
        head = s.admission_order(q, now=0.0)[0]
        s.note_service(head.priority, 4)       # serve the chosen class
    svc = s.class_service()
    assert svc[1] / svc[0] == pytest.approx(4.0, rel=0.15)


def test_weighted_fair_aging_jumps_the_order():
    s = WeightedFairScheduler(weights={0: 1.0, 1: 50.0}, starve_ms=100.0,
                              quantum=8)
    old_low = _req(rid=0, priority=0, submit_t=0.0)
    high = _req(rid=1, priority=1, submit_t=0.04)
    assert s.admission_order([old_low, high], now=0.05)[0] is high
    # past the bound the aged request leads regardless of credit
    assert s.admission_order([old_low, high], now=0.2)[0] is old_low
    assert s.starved_out([old_low], [], now=1e9) == []  # escalate, not fail


def test_weighted_fair_victim_is_most_over_share():
    s = WeightedFairScheduler(weights={0: 1.0, 1: 4.0}, quantum=8)
    s._credit = {0: -4.0, 1: -8.0}     # normalized: 0 is 4 over, 1 is 2
    cands = [VictimCandidate(slot=0, priority=0, slack=0.0, remaining=1),
             VictimCandidate(slot=1, priority=1, slack=99.0, remaining=99)]
    assert s.preempt_victim(cands, []) == 0
    assert s.preempt_victim([], []) is None


def test_weighted_fair_interleave_tracks_weight_ratio():
    s = WeightedFairScheduler(weights={0: 1.0, 1: 4.0})
    assert s.interleave_share([1], [0]) == 1.0     # 4/5 * 2 clamped
    assert s.interleave_share([0], [1]) == pytest.approx(0.4)
    assert s.interleave_share([0], [0, 0, 0]) == 0.5
    assert s.interleave_share([], [1]) == 1.0


# --------------------------------------------------------- engine behaviour

def _engine(arch="dense", **kw):
    cfg, params = _setup(arch)
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 48)
    kw.setdefault("decode_block", 4)
    kw.setdefault("chunk_size", 8)
    return cfg, ServingEngine(cfg, params, **kw)


def test_engine_default_scheduler_is_fifo():
    _, eng = _engine()
    assert eng.scheduler.policy == "fifo"


def _policy_outputs(arch, policy, *, lens=(9, 6, 11, 7), max_new=8,
                    **kw):
    cfg, eng = _engine(arch,
                       scheduler=make_scheduler(policy, {0: 1.0, 1: 4.0}),
                       **kw)
    for i, p in enumerate(_prompts(cfg, lens=lens)):
        eng.submit(Request(rid=i, prompt=p, max_new=max_new,
                           priority=i % 2))
    eng.run(max_iters=500)
    assert all(r.status == "ok" for r in eng.finished), \
        (policy, [(r.rid, r.status) for r in eng.finished])
    return {r.rid: list(r.out) for r in eng.finished}


def test_policy_bit_identity():
    ref = _policy_outputs("dense", "fifo")
    for policy in POLICIES[1:]:
        assert _policy_outputs("dense", policy) == ref, policy


@pytest.mark.slow
@pytest.mark.parametrize("arch,backend", [
    ("dense", "ref"), ("mamba2", "ref"), ("hybrid", "ref"),
    ("dense", "interpret"), ("mamba2", "interpret"),
    ("hybrid", "interpret"),
])
def test_policy_bit_identity_sweep(arch, backend):
    with dispatch.use_backend(backend):
        ref = _policy_outputs(arch, "fifo")
        for policy in POLICIES[1:]:
            assert _policy_outputs(arch, policy) == ref, (arch, policy)


def test_strict_tier_preemption_restores_bit_exact_across_buckets():
    """A high-class arrival evicts the low-class slot mid-decode AFTER
    its KV prefix climbed past the 128 bucket rung; the restored request
    must finish with the solo run's exact tokens (blob restore rebuilds
    the ladder state, policy only chose the victim)."""
    cfg, _ = _engine()
    rng = np.random.default_rng(11)
    low_prompt = rng.integers(2, cfg.vocab_size, 120).astype(np.int32)
    high_prompt = rng.integers(2, cfg.vocab_size, 9).astype(np.int32)
    kw = dict(slots=1, max_seq=192, chunk_size=32,
              scheduler=StrictTiersScheduler())

    _, solo = _engine(**kw)
    solo.submit(Request(rid=0, prompt=low_prompt, max_new=16, priority=0))
    solo.run(max_iters=500)
    ref = {r.rid: list(r.out) for r in solo.finished}

    _, eng = _engine(**kw)
    eng.submit(Request(rid=0, prompt=low_prompt, max_new=16, priority=0))
    # decode until the low request's KV prefix crosses the 128 rung
    while not (eng.live[0] is not None and len(eng.live[0].out) >= 10):
        assert eng.step()
    assert int(eng.pos[0]) > 128
    eng.submit(Request(rid=1, prompt=high_prompt, max_new=8, priority=1))
    eng.run(max_iters=500)
    done = {r.rid: r for r in eng.finished}
    assert eng.stats["preemptions"] >= 1
    # high class finished first (it preempted), both bit-exact
    assert [r.rid for r in eng.finished][0] == 1
    assert done[0].status == "ok" and list(done[0].out) == ref[0]

    _, hsolo = _engine(**kw)
    hsolo.submit(Request(rid=1, prompt=high_prompt, max_new=8, priority=1))
    hsolo.run(max_iters=500)
    assert list(done[1].out) == list(hsolo.finished[0].out)


def _starve_workload(policy, clock, *, starve_ms, n_high=10):
    """Sustained high-class load: a couple of high requests plus one
    low-class request up front, then a drip of FRESH high arrivals while
    the engine runs — the scenario where credit order alone would push
    the low request back forever (each new arrival outranks it)."""
    cfg, eng = _engine(slots=1, clock=clock,
                       scheduler=make_scheduler(policy, {0: 1.0, 1: 50.0},
                                                starve_ms))
    rng = np.random.default_rng(5)

    def high(i):
        p = rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
        return Request(rid=i, prompt=p, max_new=8, priority=1)

    eng.submit(high(0))
    eng.submit(high(1))
    eng.submit(Request(rid=99, prompt=rng.integers(
        2, cfg.vocab_size, 8).astype(np.int32), max_new=8, priority=0))
    nxt, steps = 2, 0
    while (eng.step() or eng.queue) and steps < 2000:
        steps += 1
        if nxt < n_high:                 # one fresh arrival per step:
            eng.submit(high(nxt))        # arrivals outpace the slot
            nxt += 1
    assert nxt == n_high                 # the drip actually all arrived
    return eng, {r.rid: r for r in eng.finished}


def test_weighted_fair_aging_beats_sustained_high_load():
    """Under a sustained drip of high-class arrivals (weights 1:50 —
    credit alone would let every fresh arrival outrank the low class
    forever) the aging bound must get the low request served
    mid-backlog, with zero starvation timeouts."""
    eng, done = _starve_workload("weighted_fair", FakeClock(tick_ms=1.0),
                                 starve_ms=40.0)
    assert done[99].status == "ok"
    assert eng.stats["starvation_timeouts"] == 0
    order = [r.rid for r in eng.finished]
    # served before the tail of the drip, not dead last
    assert order.index(99) < len(order) - 3, order
    ttft = eng.telemetry.class_summary()[0]["ttft_p95_ms"]
    assert ttft is not None and ttft > 0.0


def test_strict_tiers_enforces_starvation_bound():
    eng, done = _starve_workload("strict_tiers", FakeClock(tick_ms=1.0),
                                 starve_ms=40.0)
    assert done[99].status == "timed_out"
    assert isinstance(done[99].error, StarvationTimeout)
    assert not done[99].out                          # never served
    assert eng.stats["starvation_timeouts"] == 1
    assert all(done[i].status == "ok" for i in range(10))
