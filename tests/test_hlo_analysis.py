"""HLO cost-analyzer tests: scan trip-count multiplication, class
attribution via named_scope, and dot-FLOP accounting."""
import jax
import jax.numpy as jnp

from repro.core.hlo_analysis import analyze_compiled, parse_hlo, xla_cost_dict


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_trip_count_multiplied():
    D, L = 256, 8
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)

    def f_scan(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    def f_unroll(x, w):
        for i in range(L):
            x = x @ w[i]
        return x

    s1 = analyze_compiled(_compile(f_scan, x, w))
    s2 = analyze_compiled(_compile(f_unroll, x, w))
    expected = 2 * D * D * D * L
    assert abs(s1.flops - expected) / expected < 0.05
    assert abs(s1.flops - s2.flops) / expected < 0.05
    # XLA's own aggregate (known limitation): undercounts the scan body.
    xla = xla_cost_dict(_compile(f_scan, x, w)).get("flops", 0)
    assert xla < 0.5 * expected


def test_scope_classification():
    D = 128
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def f(x):
        with jax.named_scope("ssm_core"):
            y = jnp.exp(x) * 2.0
        with jax.named_scope("mlp"):
            y = y @ y
        with jax.named_scope("norm"):
            y = y / jnp.sqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6)
        return y

    s = analyze_compiled(_compile(f, x))
    cls = s.by_class()
    assert cls.get("ssm", {}).get("flops", 0) > 0, "ssm scope missed"
    assert cls.get("gemm", {}).get("flops", 0) >= 2 * D * D * D * 0.9
    assert cls.get("norm", {}).get("flops", 0) > 0


def test_dot_flops_exact():
    M, K, N = 64, 128, 32
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    s = analyze_compiled(_compile(lambda a, b: a @ b, a, b))
    gemm = s.by_class()["gemm"]["flops"]
    assert gemm == 2 * M * K * N


def test_bytes_nonzero_and_fusion_model():
    D = 512
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    s = analyze_compiled(_compile(lambda x: jnp.tanh(x) * 2.0 + 1.0, x))
    # fused elementwise chain ≈ one kernel: read + write ≈ 2 * D*D*4
    assert s.bytes <= 3 * D * D * 4
    assert s.bytes >= 1.5 * D * D * 4


def test_parse_hlo_structure():
    D = 64
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    txt = _compile(lambda x: x @ x, x).as_text()
    comps = parse_hlo(txt)
    assert "__entry__" in comps
    assert any(op.opcode == "dot" for ops in comps.values() for op in ops)
