"""KV bucketing: selector properties (edges included) and bit-exactness of
bucket-sliced prefill/decode against the full-cache programs.

The boundary case is the load-bearing one: a prefix landing exactly on a
rung (``pos + chunk == bucket``) must select that rung — one rung lower
would drop the newest KV row (a stale-read at decode), one higher is a
spurious recompile."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import (decode_tokens, init_lm_cache, init_lm_params,
                             lm_prefill, lm_prefill_chunk)
from repro.serving.bucketing import (MIN_BUCKET, bucket_ladder,
                                     kv_cache_extent, select_kv_bucket)
from repro.serving.prefill import chunked_prefill

KEY = jax.random.PRNGKey(0)


def _dense_cfg():
    return ModelConfig(
        name="dense", family="dense", n_layers=2, d_model=64, d_ff=128,
        vocab_size=97, attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        layer_pattern=("dense",), vocab_pad_multiple=16)


def _hybrid_cfg():
    return ModelConfig(
        name="hybrid", family="hybrid", n_layers=4, d_model=64, d_ff=0,
        vocab_size=97, ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
        layer_pattern=("mamba2", "mamba2+shared"),
        shared_attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        shared_attn_d_ff=128, vocab_pad_multiple=16)


# ------------------------------------------------------------- the selector
def test_ladder_shape():
    lad = bucket_ladder(4096)
    assert lad[0] == MIN_BUCKET and lad[-1] == 4096
    assert list(lad) == sorted(set(lad))
    assert len(lad) <= 2 + int(np.log2(4096 / MIN_BUCKET))
    # non-power-of-two max_seq still tops the ladder
    assert bucket_ladder(5000)[-1] == 5000
    # tiny max_seq: a single full-cache rung
    assert bucket_ladder(64) == (64,)


@pytest.mark.parametrize("max_seq", [256, 1000, 4096])
def test_selector_minimal_and_monotone(max_seq):
    lad = bucket_ladder(max_seq)
    prev = 0
    for needed in range(1, max_seq + 1):
        b = select_kv_bucket(needed, max_seq)
        assert b >= needed, (needed, b)                   # never a stale row
        assert b in lad
        smaller = [r for r in lad if needed <= r < b]
        assert not smaller, f"non-minimal rung {b} for {needed}"
        assert b >= prev                                   # monotone in prefix
        prev = b
    # compile count over a whole ramp == rungs actually needed
    used = {select_kv_bucket(n, max_seq) for n in range(1, max_seq + 1)}
    assert used == set(lad)


def test_selector_edges_exact():
    """needed == rung selects that rung; needed == rung + 1 the next."""
    max_seq = 4096
    for rung in bucket_ladder(max_seq):
        assert select_kv_bucket(rung, max_seq) == rung
        if rung > 1:
            assert select_kv_bucket(rung - 1, max_seq) == rung
        if rung < max_seq:
            nxt = select_kv_bucket(rung + 1, max_seq)
            assert nxt > rung and nxt == min(
                r for r in bucket_ladder(max_seq) if r > rung)


def test_selector_rejects_overflow():
    with pytest.raises(ValueError):
        select_kv_bucket(4097, 4096)


def test_selector_property_sweep():
    """Hypothesis sweep around every edge of randomized ladders."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(max_seq=st.integers(MIN_BUCKET, 1 << 16),
           jitter=st.integers(-1, 1),
           rung_idx=st.integers(0, 12))
    def check(max_seq, jitter, rung_idx):
        lad = bucket_ladder(max_seq)
        rung = lad[min(rung_idx, len(lad) - 1)]
        needed = min(max(rung + jitter, 1), max_seq)
        b = select_kv_bucket(needed, max_seq)
        assert needed <= b <= max_seq
        assert not [r for r in lad if needed <= r < b]

    check()


# ----------------------------------------------- bit-exactness at the edges
@pytest.mark.parametrize("arch", [
    "dense", pytest.param("hybrid", marks=pytest.mark.slow)])
def test_chunk_bucket_edge_bit_exact(arch):
    """A chunk whose end lands exactly on its bucket (pos + chunk == bucket)
    must produce byte-identical logits and cache to the unbucketed step —
    the newest KV row sits at index bucket-1 and must not be dropped."""
    cfg = {"dense": _dense_cfg, "hybrid": _hybrid_cfg}[arch]()
    params = init_lm_params(cfg, KEY)
    B, C, MS = 2, 8, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 4 * C), 0,
                              cfg.vocab_size, jnp.int32)
    cache_b = init_lm_cache(cfg, B, MS)
    cache_f = init_lm_cache(cfg, B, MS)
    step = jax.jit(
        lambda p, t, c, kv_bucket: lm_prefill_chunk(
            cfg, p, {"tokens": t}, c, kv_bucket=kv_bucket),
        static_argnames=("kv_bucket",))
    for i in range(4):
        chunk = toks[:, i * C:(i + 1) * C]
        # exact edge: the bucket is precisely the prefix written so far
        lg_b, cache_b = step(params, chunk, cache_b, (i + 1) * C)
        lg_f, cache_f = step(params, chunk, cache_f, None)
        np.testing.assert_array_equal(np.asarray(lg_b), np.asarray(lg_f))
    for a, b in zip(jax.tree_util.tree_leaves(cache_b),
                    jax.tree_util.tree_leaves(cache_f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", [
    "dense", pytest.param("hybrid", marks=pytest.mark.slow)])
def test_decode_bucket_edge_bit_exact(arch):
    """decode_tokens under the tightest legal bucket (max(pos) + n) must
    emit the same tokens and cache as the full-cache burst."""
    cfg = {"dense": _dense_cfg, "hybrid": _hybrid_cfg}[arch]()
    params = init_lm_params(cfg, KEY)
    B, L, MS, N = 2, 13, 96, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                              cfg.vocab_size, jnp.int32)
    logits, cache = lm_prefill(cfg, params, {"tokens": toks},
                               init_lm_cache(cfg, B, MS))
    first = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    t_full, c_full = decode_tokens(cfg, params, cache, first, N)
    t_b, c_b = decode_tokens(cfg, params, cache, first, N,
                             kv_bucket=L + N)          # the exact edge
    np.testing.assert_array_equal(np.asarray(t_b), np.asarray(t_full))
    for a, b in zip(jax.tree_util.tree_leaves(c_b),
                    jax.tree_util.tree_leaves(c_full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_prefill_buckets_match_oneshot():
    """The serving helper with bucketing on (its default) still reproduces
    one-shot prefill: logits and an 8-token greedy continuation."""
    cfg = _dense_cfg()
    params = init_lm_params(cfg, KEY)
    B, L, MS = 2, 21, 200
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, L), 0,
                              cfg.vocab_size, jnp.int32)
    ref_logits, ref_cache = lm_prefill(cfg, params, {"tokens": toks},
                                       init_lm_cache(cfg, B, MS))
    logits, cache = chunked_prefill(cfg, params, toks,
                                    init_lm_cache(cfg, B, MS), chunk_size=8)
    # bf16 logits: tolerance above bf16 ULP; the bit-exact continuation
    # below is the strong gate
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
    first = jnp.argmax(ref_logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    t_ref, _ = decode_tokens(cfg, params, ref_cache, first, 8)
    t_chk, _ = decode_tokens(cfg, params, cache, first, 8)
    np.testing.assert_array_equal(np.asarray(t_chk), np.asarray(t_ref))


def _local_cfg(window=16, pure=False):
    return ModelConfig(
        name=f"local{window}{'p' if pure else ''}", family="dense",
        n_layers=2, d_model=64, d_ff=128, vocab_size=97,
        compute_dtype="float32",
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                        sliding_window=window),
        layer_pattern=("local",) if pure else ("local", "dense"),
        vocab_pad_multiple=16)


def test_kv_bucket_rejects_encoder_only():
    """Encoders (bidirectional) still refuse buckets; rolling windows now
    ride the ladder (ring-aware slicing) instead of being rejected."""
    enc = ModelConfig(
        name="enc", family="encoder", n_layers=2, d_model=64, d_ff=128,
        vocab_size=97,
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16, causal=False),
        layer_pattern=("encoder",), vocab_pad_multiple=16)
    with pytest.raises(ValueError):
        lm_prefill_chunk(enc, init_lm_params(enc, KEY),
                         {"tokens": jnp.zeros((1, 4), jnp.int32)},
                         init_lm_cache(enc, 1, 32), kv_bucket=16)


def test_kv_cache_extent_window_cap():
    """The ladder top is the model's largest KV leaf: max_seq for
    append-only caches, the window for rolling ones — including the
    window > max_seq corner where the rolling cache outsizes max_seq."""
    assert kv_cache_extent(_local_cfg(window=16), 64) == 64   # dense wins
    assert kv_cache_extent(_local_cfg(window=16, pure=True), 64) == 16
    assert kv_cache_extent(_local_cfg(window=16, pure=True), 12) == 16
    assert kv_cache_extent(_dense_cfg(), 64) == 64
    assert kv_cache_extent(_hybrid_cfg(), 64) == 64
    ssm_only = ModelConfig(
        name="ssm", family="ssm", n_layers=2, d_model=64, d_ff=0,
        vocab_size=97, ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
        layer_pattern=("mamba2",), vocab_pad_multiple=16)
    assert kv_cache_extent(ssm_only, 64) is None


@pytest.mark.slow
def test_ring_bucket_slice_bit_exact():
    """Bucket-slicing a not-yet-wrapped ring: chunks at pos + chunk <=
    bucket < window must produce byte-identical logits and caches to the
    unbucketed step, and once the prefix wraps the full-window rung takes
    over (the serving selection rule ``min(pos + chunk, extent)``)."""
    cfg = _local_cfg(window=16)
    params = init_lm_params(cfg, KEY)
    B, C, MS = 2, 8, 64
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, 4 * C), 0,
                              cfg.vocab_size, jnp.int32)
    cache_b = init_lm_cache(cfg, B, MS)
    cache_f = init_lm_cache(cfg, B, MS)
    step = jax.jit(
        lambda p, t, c, kv_bucket: lm_prefill_chunk(
            cfg, p, {"tokens": t}, c, kv_bucket=kv_bucket),
        static_argnames=("kv_bucket",))
    for i in range(4):
        chunk = toks[:, i * C:(i + 1) * C]
        # serving rule: smallest extent covering pos + chunk, capped at the
        # largest leaf — rungs 8, 16 slice the window-16 ring (no wrap
        # yet), 24+ leave it whole and slice only the dense leaves
        bucket = min((i + 1) * C, 64)
        lg_b, cache_b = step(params, chunk, cache_b, bucket)
        lg_f, cache_f = step(params, chunk, cache_f, None)
        np.testing.assert_array_equal(np.asarray(lg_b), np.asarray(lg_f))
    for a, b in zip(jax.tree_util.tree_leaves(cache_b),
                    jax.tree_util.tree_leaves(cache_f)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rolling_decode_bucketed_matches_full():
    """decode_tokens on a rolling arch under the extent-capped bucket must
    emit the same tokens as the full-cache burst, across a ring wrap."""
    cfg = _local_cfg(window=16, pure=True)
    params = init_lm_params(cfg, KEY)
    B, L, MS, N = 2, 13, 96, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0,
                              cfg.vocab_size, jnp.int32)
    logits, cache = lm_prefill(cfg, params, {"tokens": toks},
                               init_lm_cache(cfg, B, MS))
    first = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    t_full, c_full = decode_tokens(cfg, params, cache, first, N,
                                   rope_len=MS)
    # pos runs 13 -> 21, crossing window 16: the extent rung (= window)
    # is the only legal bucket once wrapped
    t_b, c_b = decode_tokens(cfg, params, cache, first, N,
                             kv_bucket=16, rope_len=MS)
    np.testing.assert_array_equal(np.asarray(t_b), np.asarray(t_full))
    for a, b in zip(jax.tree_util.tree_leaves(c_b),
                    jax.tree_util.tree_leaves(c_full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
