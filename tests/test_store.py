"""Crash-durability matrix: a ServingEngine killed at exact points must
restart from its durable :class:`CheckpointStore` and resume every
request's token stream BIT-IDENTICALLY with an uninterrupted run.

Kill points (deterministic ``kill`` fault clauses raising
``SimulatedCrash``): mid-prefill, mid-decode, between checkpoint stage
and manifest commit, and post-completion of one co-batched request.
Damage tolerance: a torn (truncated) blob file degrades that request to
replay-from-prompt (still bit-identical), a torn manifest cold-starts
the store, a foreign layout fingerprint is refused, and a record whose
prompt fails its crc is the only unrecoverable case (``RecoveryFailed``).
Deadlines survive restart as REMAINING budget against the injectable
clock — expired-while-down requests fail at rehydration, before any
replay work is wasted.

The tier-1 subset runs the four kill points on the hybrid toy config
(the richest cache pytree: SSM state + shared-attention KV); the slow
sweep extends to dense/mamba2 × ref/interpret backends."""
import json
from functools import lru_cache

import jax
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.kernels import dispatch
from repro.models.lm import init_lm_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.fault_inject import FaultPlan, SimulatedCrash, parse_spec
from repro.serving.faults import DeadlineExceeded, RecoveryFailed
from repro.serving.store import MANIFEST_NAME, CheckpointStore

KEY = jax.random.PRNGKey(0)

# kill points of the matrix: spec -> where the process dies.
# iter=1: rid1 (short prompt) is live, rid0 still mid-prefill.
# iter=2: both rids decoding, each with a committed durable checkpoint.
# iter=2:point=1: blob files staged, manifest commit never lands.
# iter=4: rid0 already finished (forgotten from the store) pre-crash.
KILL_SPECS = {
    "mid_prefill": "kill@iter=1",
    "mid_decode": "kill@iter=2",
    "ckpt_manifest_gap": "kill@iter=2:point=1",
    "post_completion": "kill@iter=4",
}

#: per-rid decode budgets: rid0 finishes early (exercising terminal
#: forget), rid1 decodes long enough to cross several checkpoints
MAX_NEW = (6, 24)

ENG_KW = dict(slots=2, max_seq=48, decode_block=4, chunk_size=8,
              checkpoint_every=2)


def _cfg(arch: str) -> ModelConfig:
    if arch == "dense":
        return ModelConfig(name="dense", family="dense", n_layers=2,
                           d_model=64, d_ff=128, vocab_size=97,
                           attn=AttnConfig(n_heads=4, n_kv_heads=2,
                                           head_dim=16),
                           layer_pattern=("dense",), vocab_pad_multiple=16)
    if arch == "mamba2":
        return ModelConfig(name="mamba2", family="ssm", n_layers=2,
                           d_model=64, d_ff=0, vocab_size=97,
                           ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                           layer_pattern=("mamba2",), vocab_pad_multiple=16)
    assert arch == "hybrid"
    return ModelConfig(name="hyb", family="hybrid", n_layers=4, d_model=64,
                       d_ff=0, vocab_size=97,
                       ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                       layer_pattern=("mamba2", "mamba2+shared"),
                       shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                              head_dim=16),
                       shared_attn_d_ff=128, vocab_pad_multiple=16)


@lru_cache(maxsize=None)
def _setup(arch: str):
    cfg = _cfg(arch)
    return cfg, init_lm_params(cfg, KEY)


def _prompts(cfg, lens=(9, 6), seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, int(n)).astype(np.int32)
            for n in lens]


class FakeClock:
    """Injectable engine clock (seconds, monotonic-shaped).  Shared
    between a crashed engine and its successor, it models wall time
    flowing THROUGH the crash — the remaining-deadline-budget tests
    depend on that continuity."""

    def __init__(self, tick_ms=0.0):
        self.t = 0.0
        self.tick = tick_ms / 1e3

    def __call__(self):
        self.t += self.tick
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


def _engine(arch, store=None, plan=None, clock=None, decode_n=None):
    cfg, params = _setup(arch)
    eng = ServingEngine(cfg, params, fault_plan=plan, store=store,
                        clock=clock, **ENG_KW)
    if decode_n is not None:
        # share the jitted decode callable so restarted engines hit the
        # executable cache instead of re-paying XLA compiles per engine
        eng._decode_n = decode_n
    return eng


def _submit_all(eng, prompts, deadline_ms=None):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=MAX_NEW[i],
                           deadline_ms=deadline_ms))


# reference (uninterrupted) outputs per (arch, backend), computed once —
# the shared decode callable rides along for the crash/restart engines
_REF_CACHE = {}


def _reference(arch, backend="default"):
    key = (arch, backend)
    if key not in _REF_CACHE:
        cfg, _ = _setup(arch)
        eng = _engine(arch)
        _submit_all(eng, _prompts(cfg))
        eng.run(max_iters=300)
        assert all(r.status == "ok" for r in eng.finished)
        _REF_CACHE[key] = ({r.rid: list(r.out) for r in eng.finished},
                           eng._decode_n)
    return _REF_CACHE[key]


def _crash_and_restart(arch, spec, store_dir, backend="default"):
    """Run the crash → restart → resume protocol and assert the combined
    decoded streams are bit-identical to the uninterrupted reference.
    Returns (crashed engine, restarted engine)."""
    cfg, _ = _setup(arch)
    ref_out, decode_n = _reference(arch, backend)
    eng1 = _engine(arch, store=CheckpointStore(store_dir),
                   plan=FaultPlan.from_spec(spec), decode_n=decode_n)
    _submit_all(eng1, _prompts(cfg))
    with pytest.raises(SimulatedCrash):
        eng1.run(max_iters=300)
    pre_ok = {r.rid: list(r.out) for r in eng1.finished
              if r.status == "ok"}
    eng2 = _engine(arch, store=CheckpointStore(store_dir),
                   decode_n=decode_n)
    eng2.run(max_iters=300)
    assert all(r.status == "ok" for r in eng2.finished), \
        [(r.rid, r.status, str(r.error)) for r in eng2.finished]
    combined = dict(pre_ok)
    combined.update({r.rid: list(r.out) for r in eng2.finished})
    assert combined == ref_out
    return eng1, eng2


# ------------------------------------------------------------- kill matrix
@pytest.mark.parametrize("point", sorted(KILL_SPECS))
def test_kill_point_recovers_bit_identical(point, tmp_path):
    eng1, eng2 = _crash_and_restart("hybrid", KILL_SPECS[point],
                                    str(tmp_path / "store"))
    rec = eng2.recovery
    if point == "mid_prefill":
        # the long prompt never reached a checkpoint: replayed as a
        # fresh queued admission with its original priority
        assert rec["requeued"] + rec["replayed"] >= 1
    if point in ("mid_decode", "ckpt_manifest_gap"):
        assert rec["restored"] >= 1
    if point == "post_completion":
        # rid0 finished pre-crash: its record was forgotten, only rid1
        # survives in the store — completed work is never re-decoded
        assert sum(rec.values()) == 1
        assert any(r.rid == 0 and r.status == "ok"
                   for r in eng1.finished)
    assert rec["expired"] == rec["unrecoverable"] == 0


@pytest.mark.slow
@pytest.mark.parametrize("arch,backend", [
    ("dense", "ref"), ("mamba2", "ref"),
    ("dense", "interpret"), ("mamba2", "interpret"),
    ("hybrid", "interpret"),
])
@pytest.mark.parametrize("point", sorted(KILL_SPECS))
def test_kill_matrix_sweep(arch, backend, point, tmp_path):
    with dispatch.use_backend(backend):
        _crash_and_restart(arch, KILL_SPECS[point],
                           str(tmp_path / "store"), backend=backend)


# --------------------------------------------------------- damage handling
def test_torn_blob_replays_from_prompt(tmp_path):
    """Every durable blob truncated to half: restart must degrade to
    replay-from-prompt (CacheCorruption handled, never raised) and the
    replayed streams stay bit-identical."""
    store_dir = tmp_path / "store"
    cfg, _ = _setup("hybrid")
    ref_out, decode_n = _reference("hybrid")
    eng1 = _engine("hybrid", store=CheckpointStore(str(store_dir)),
                   plan=FaultPlan.from_spec("kill@iter=2"),
                   decode_n=decode_n)
    _submit_all(eng1, _prompts(cfg))
    with pytest.raises(SimulatedCrash):
        eng1.run(max_iters=300)
    blobs = list((store_dir / "blobs").glob("*.blob"))
    assert blobs
    for f in blobs:
        f.write_bytes(f.read_bytes()[:max(1, f.stat().st_size // 2)])
    eng2 = _engine("hybrid", store=CheckpointStore(str(store_dir)),
                   decode_n=decode_n)
    assert eng2.recovery["replayed"] >= 1
    assert eng2.recovery["restored"] == 0
    eng2.run(max_iters=300)
    assert {r.rid: list(r.out) for r in eng2.finished} == ref_out
    assert all(r.status == "ok" for r in eng2.finished)


def test_torn_manifest_cold_starts(tmp_path):
    store_dir = tmp_path / "store"
    cfg, _ = _setup("hybrid")
    _, decode_n = _reference("hybrid")
    eng1 = _engine("hybrid", store=CheckpointStore(str(store_dir)),
                   plan=FaultPlan.from_spec("kill@iter=2"),
                   decode_n=decode_n)
    _submit_all(eng1, _prompts(cfg))
    with pytest.raises(SimulatedCrash):
        eng1.run(max_iters=300)
    (store_dir / MANIFEST_NAME).write_bytes(b'{"version": 1, "requ')
    eng2 = _engine("hybrid", store=CheckpointStore(str(store_dir)),
                   decode_n=decode_n)
    # nothing consistent to recover -> cold store, zero rehydrations,
    # and the engine still serves fresh work through the same store
    assert sum(eng2.recovery.values()) == 0
    _submit_all(eng2, _prompts(cfg))
    eng2.run(max_iters=300)
    assert all(r.status == "ok" for r in eng2.finished)


def test_foreign_fingerprint_refused(tmp_path):
    """A store written under a different config/cache layout is ignored
    (never adopted, never overwritten) — the engine comes up empty."""
    store_dir = str(tmp_path / "store")
    cfg, _ = _setup("hybrid")
    _, decode_n = _reference("hybrid")
    eng1 = _engine("hybrid", store=CheckpointStore(store_dir),
                   plan=FaultPlan.from_spec("kill@iter=2"),
                   decode_n=decode_n)
    _submit_all(eng1, _prompts(cfg))
    with pytest.raises(SimulatedCrash):
        eng1.run(max_iters=300)
    eng2 = _engine("dense", store=CheckpointStore(store_dir))
    assert eng2.store is None
    assert sum(eng2.recovery.values()) == 0
    # the hybrid records are still intact on disk for the RIGHT engine
    eng3 = _engine("hybrid", store=CheckpointStore(store_dir),
                   decode_n=decode_n)
    assert sum(eng3.recovery.values()) == 2


def test_tampered_prompt_is_unrecoverable(tmp_path):
    """prompt crc mismatch is the one non-degradable damage: replay
    would decode a DIFFERENT request, so rehydration fails the record
    with RecoveryFailed instead of quietly serving wrong tokens."""
    store_dir = tmp_path / "store"
    cfg, _ = _setup("hybrid")
    _, decode_n = _reference("hybrid")
    eng1 = _engine("hybrid", store=CheckpointStore(str(store_dir)),
                   plan=FaultPlan.from_spec("kill@iter=2"),
                   decode_n=decode_n)
    _submit_all(eng1, _prompts(cfg))
    with pytest.raises(SimulatedCrash):
        eng1.run(max_iters=300)
    man_path = store_dir / MANIFEST_NAME
    man = json.loads(man_path.read_text())
    man["requests"]["0"]["prompt"][0] += 1
    man_path.write_text(json.dumps(man))
    eng2 = _engine("hybrid", store=CheckpointStore(str(store_dir)),
                   decode_n=decode_n)
    assert eng2.recovery["unrecoverable"] == 1
    bad = [r for r in eng2.finished if r.rid == 0]
    assert bad and bad[0].status == "failed"
    assert isinstance(bad[0].error, RecoveryFailed)
    eng2.run(max_iters=300)
    good = {r.rid: r for r in eng2.finished}
    assert good[1].status == "ok"


# ----------------------------------------------------------- deadlines
def test_deadline_expired_while_down_fails_at_rehydration(tmp_path):
    """Budget consumed by downtime: the request must fail with
    DeadlineExceeded AT CONSTRUCTION — zero replay iterations wasted."""
    clock = FakeClock()
    store_dir = str(tmp_path / "store")
    cfg, _ = _setup("hybrid")
    _, decode_n = _reference("hybrid")
    eng1 = _engine("hybrid", store=CheckpointStore(store_dir),
                   plan=FaultPlan.from_spec("kill@iter=2"), clock=clock,
                   decode_n=decode_n)
    _submit_all(eng1, _prompts(cfg), deadline_ms=50.0)
    with pytest.raises(SimulatedCrash):
        eng1.run(max_iters=300)
    clock.advance_ms(200.0)          # the engine stays dead past the TTL
    eng2 = _engine("hybrid", store=CheckpointStore(store_dir),
                   clock=clock, decode_n=decode_n)
    assert eng2.recovery["expired"] == 2
    assert eng2.stats["iters"] == 0
    for r in eng2.finished:
        assert r.status == "timed_out"
        assert isinstance(r.error, DeadlineExceeded)


def test_deadline_resumes_as_remaining_budget(tmp_path):
    """The restarted engine must charge the budget already consumed
    pre-crash + downtime — NOT restart the TTL.  150ms deadline, 100ms
    burned before the crash, 40ms down: the request rehydrates (140 <
    150) but 20ms more wall time expires it — a full-TTL reset would
    have left 130ms of headroom and finished ok."""
    clock = FakeClock()
    store_dir = str(tmp_path / "store")
    cfg, _ = _setup("hybrid")
    _, decode_n = _reference("hybrid")
    eng1 = _engine("hybrid", store=CheckpointStore(store_dir),
                   plan=FaultPlan.from_spec("kill@iter=4"), clock=clock,
                   decode_n=decode_n)
    eng1.submit(Request(rid=1, prompt=_prompts(cfg)[1], max_new=MAX_NEW[1],
                        deadline_ms=150.0))
    clock.advance_ms(100.0)          # pre-crash queue/decode wall time
    with pytest.raises(SimulatedCrash):
        eng1.run(max_iters=300)
    clock.advance_ms(40.0)           # downtime: consumed 140 < 150
    eng2 = _engine("hybrid", store=CheckpointStore(store_dir),
                   clock=clock, decode_n=decode_n)
    assert eng2.recovery["expired"] == 0
    assert sum(eng2.recovery.values()) == 1
    clock.advance_ms(20.0)           # consumed 160 > 150: must expire
    eng2.run(max_iters=300)
    (req,) = eng2.finished
    assert req.status == "timed_out"
    assert isinstance(req.error, DeadlineExceeded)


# ------------------------------------------------------------- kill spec
def test_kill_spec_grammar():
    (c,) = parse_spec("kill@iter=5:point=1:n=2")
    assert c.kind == "kill"
    assert c.params == {"iter": 5, "point": 1, "n": 2}
    plan = FaultPlan.from_spec("kill@iter=3")
    assert not plan.kill_now(2)
    assert not plan.kill_now(3, point=1)   # wrong crash point
    assert plan.kill_now(3)
    assert not plan.kill_now(4)            # budget n=1 spent
    with pytest.raises(ValueError):
        parse_spec("kill@point=1")         # iter is required
