"""Data-pipeline determinism + optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import DataConfig, SyntheticLM
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def test_data_restart_determinism():
    cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=4, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 5, 17):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])


def test_data_needle_planted():
    cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=2, seed=0)
    b = SyntheticLM(cfg).batch(0)
    toks = b["tokens"]
    nl = cfg.needle_len
    ins = int(cfg.seq_len * cfg.needle_offset_frac * 0.5)
    rep = cfg.seq_len - 2 * nl - 1
    np.testing.assert_array_equal(toks[:, ins:ins + nl], toks[:, rep:rep + nl])


@pytest.mark.slow
def test_adamw_converges_quadratic():
    opt = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params, opt)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, opt)
    assert float(loss(params)) < 1e-3


def test_grad_clipping():
    opt = OptConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, opt)
    huge = {"w": jnp.full(3, 1e6)}
    _, _, metrics = adamw_update(params, huge, state, opt)
    assert float(metrics["grad_norm"]) > 1e5      # reported pre-clip
    # post-clip update must be bounded by ~lr
    p2, _, _ = adamw_update(params, huge, state, opt)
    assert float(jnp.abs(p2["w"]).max()) < 0.1
