"""Model-behaviour tests: the strong decode-vs-forward equivalence — decode
token-by-token with caches must reproduce full-sequence forward logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models import (init_lm_cache, init_lm_params, lm_decode_step,
                          lm_forward, lm_prefill)

KEY = jax.random.PRNGKey(0)


def _cfgs():
    return [
        ModelConfig(name="dense", family="dense", n_layers=3, d_model=64,
                    d_ff=128, vocab_size=97,
                    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
                    layer_pattern=("dense",), vocab_pad_multiple=16),
        ModelConfig(name="local", family="dense", n_layers=4, d_model=64,
                    d_ff=128, vocab_size=97, tie_embeddings=True,
                    attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=16,
                                    sliding_window=8),
                    layer_pattern=("local", "dense"), vocab_pad_multiple=16),
        ModelConfig(name="ssm2", family="ssm", n_layers=3, d_model=64, d_ff=0,
                    vocab_size=97,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                    layer_pattern=("mamba2",), vocab_pad_multiple=16),
        ModelConfig(name="ssm1", family="ssm", n_layers=2, d_model=64, d_ff=0,
                    vocab_size=97,
                    ssm=SSMConfig(d_state=8, variant="mamba1"),
                    layer_pattern=("mamba1",), vocab_pad_multiple=16),
        ModelConfig(name="hybrid", family="hybrid", n_layers=4, d_model=64,
                    d_ff=0, vocab_size=97,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                    layer_pattern=("mamba2", "mamba2+shared"),
                    shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                           head_dim=16),
                    shared_attn_d_ff=128, vocab_pad_multiple=16),
        ModelConfig(name="moe", family="moe", n_layers=2, d_model=64,
                    d_ff=128, vocab_size=97,
                    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
                    moe=MoEConfig(n_experts=4, experts_per_token=2,
                                  d_ff_expert=64, capacity_factor=2.0),
                    layer_pattern=("moe",), vocab_pad_multiple=16),
        ModelConfig(name="hybrid_par", family="hybrid", n_layers=2,
                    d_model=64, d_ff=128, vocab_size=97,
                    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                    layer_pattern=("hybrid_par",), vocab_pad_multiple=16),
    ]


@pytest.mark.parametrize("cfg", [
    # the slowest parity member runs in the slow sweep only; the
    # remaining configs still cover every layer kind in tier-1 (the
    # hybrid combination itself is exercised by test_decode_fused and
    # the serving-engine tests)
    pytest.param(c, marks=pytest.mark.slow)
    if c.name == "hybrid" else c
    for c in _cfgs()], ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    """Prefill S-k tokens, decode k: logits must match the full forward."""
    batch, seq, k = 2, 24, 4
    params = init_lm_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    full = lm_forward(cfg, params, {"tokens": tokens}, train=False)
    full = np.asarray(full[..., :cfg.vocab_size], np.float32)

    cache = init_lm_cache(cfg, batch, seq)
    lg, cache = jax.jit(lambda p, t, c: lm_prefill(
        cfg, p, {"tokens": t}, c))(params, tokens[:, :seq - k], cache)
    outs = [np.asarray(lg[:, 0, :cfg.vocab_size], np.float32)]
    step = jax.jit(lambda p, t, c: lm_decode_step(cfg, p, t, c))
    for i in range(k - 1):
        lg, cache = step(params, tokens[:, seq - k + i:seq - k + i + 1], cache)
        outs.append(np.asarray(lg[:, 0, :cfg.vocab_size], np.float32))

    ref = full[:, seq - k - 1:seq - 1]          # positions S-k-1 .. S-2
    got = np.stack(outs, axis=1)
    scale = np.abs(ref).max() + 1e-6
    err = np.abs(ref - got).max() / scale
    assert err < 3e-2, f"{cfg.name}: decode/forward mismatch rel={err:.3e}"


def test_moe_capacity_drop_monotone():
    """Lower capacity factor ⇒ more dropped tokens ⇒ output changes but
    stays finite (GShard dispatch invariant)."""
    import dataclasses
    base = next(c for c in _cfgs() if c.name == "moe")
    params = init_lm_params(base, KEY)
    tokens = jax.random.randint(KEY, (4, 16), 0, base.vocab_size, jnp.int32)
    lo = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=0.25))
    y_hi = lm_forward(base, params, {"tokens": tokens}, train=False)
    y_lo = lm_forward(lo, params, {"tokens": tokens}, train=False)
    assert np.isfinite(np.asarray(y_lo, np.float32)).all()
    assert not np.allclose(np.asarray(y_hi, np.float32),
                           np.asarray(y_lo, np.float32))


def test_vocab_padding_masked():
    cfg = _cfgs()[0]
    params = init_lm_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size, jnp.int32)
    lg = lm_forward(cfg, params, {"tokens": tokens}, train=False)
    pad = np.asarray(lg[..., cfg.vocab_size:], np.float32)
    assert (pad <= -1e29).all(), "padded vocab logits must be masked"
