"""Integration: the multi-pod dry-run machinery end-to-end in a subprocess
(512 fake devices, production mesh, real arch config)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("smollm-135m", "decode_32k")])
def test_dryrun_cell_subprocess(tmp_path, arch, shape):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open(tmp_path / f"{arch}__{shape}__single.json"))
    assert rec["applicable"] and rec["chips"] == 256
    assert rec["memory"]["fits"]
    assert rec["hlo"]["flops"] > 0 and rec["hlo"]["bytes"] > 0
    assert rec["hlo_fused"]["bytes"] <= rec["hlo"]["bytes"] * 1.01
    assert rec["model_flops"] > 0


def test_dryrun_skip_rules():
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.registry import get
    from repro.core.config import SHAPES
    from repro.core.workload import applicable
    assert not applicable(get("hubert-xlarge"), SHAPES["decode_32k"])[0]
    assert not applicable(get("llama3-8b"), SHAPES["long_500k"])[0]
    assert applicable(get("gemma3-1b"), SHAPES["long_500k"])[0]
    assert applicable(get("mamba2-2.7b"), SHAPES["long_500k"])[0]
    assert applicable(get("zamba2-2.7b"), SHAPES["long_500k"])[0]
