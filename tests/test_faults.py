"""Fault-tolerance matrix: every injected fault class must end in a
structured terminal state on ``ServingEngine.finished`` — the engine
never raises for an in-flight fault, and co-batched healthy requests
decode BIT-IDENTICALLY whether or not a neighbour slot faulted.

Covers: the ``REPRO_FAULT_SPEC`` grammar, blob integrity (crc32 + schema
fingerprint + key-set diff), divergence sentinels with checkpoint-replay
recovery, deadline admission/expiry, slack-based preemption, the
no-progress watchdog, and ``run(max_iters)``.  The slow sweep runs the
fault matrix across dense/mamba2/hybrid × ref/interpret backends."""
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.kernels import dispatch
from repro.models.lm import init_lm_cache, init_lm_params
from repro.serving.cache import (BLOB_META_KEY, offload_slot, restore_slot,
                                 validate_blob)
from repro.serving.engine import Request, ServingEngine
from repro.serving.fault_inject import FaultPlan, parse_spec, poison_slot
from repro.serving.faults import (TERMINAL_STATES, CacheCorruption,
                                  DeadlineExceeded, DivergenceDetected,
                                  RequestError, SlotStalled)

KEY = jax.random.PRNGKey(0)


def _cfg(arch: str) -> ModelConfig:
    if arch == "dense":
        return ModelConfig(name="dense", family="dense", n_layers=2,
                           d_model=64, d_ff=128, vocab_size=97,
                           attn=AttnConfig(n_heads=4, n_kv_heads=2,
                                           head_dim=16),
                           layer_pattern=("dense",), vocab_pad_multiple=16)
    if arch == "mamba2":
        return ModelConfig(name="mamba2", family="ssm", n_layers=2,
                           d_model=64, d_ff=0, vocab_size=97,
                           ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                           layer_pattern=("mamba2",), vocab_pad_multiple=16)
    assert arch == "hybrid"
    return ModelConfig(name="hyb", family="hybrid", n_layers=4, d_model=64,
                       d_ff=0, vocab_size=97,
                       ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                       layer_pattern=("mamba2", "mamba2+shared"),
                       shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                              head_dim=16),
                       shared_attn_d_ff=128, vocab_pad_multiple=16)


@lru_cache(maxsize=None)
def _setup(arch: str):
    cfg = _cfg(arch)
    return cfg, init_lm_params(cfg, KEY)


def _prompts(cfg, lens=(9, 6), seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, int(n)).astype(np.int32)
            for n in lens]


def _run(arch, plan=None, *, max_new=8, n_req=2, **kw):
    """One engine pass: submit ``n_req`` co-batched requests, run to
    completion, return {rid: Request}.  Never expects the engine to
    raise, whatever the fault plan does."""
    cfg, params = _setup(arch)
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 48)
    kw.setdefault("decode_block", 4)
    kw.setdefault("chunk_size", 8)
    eng = ServingEngine(cfg, params, fault_plan=plan, **kw)
    for i, p in enumerate(_prompts(cfg, lens=(9, 6, 11)[:n_req])):
        eng.submit(Request(rid=i, prompt=p, max_new=max_new))
    eng.run(max_iters=200)
    done = {r.rid: r for r in eng.finished}
    assert len(done) == n_req
    assert all(r.status in TERMINAL_STATES for r in done.values())
    return done, eng


class FakeClock:
    """Injectable engine clock (seconds, monotonic-shaped)."""

    def __init__(self, tick_ms=0.0):
        self.t = 0.0
        self.tick = tick_ms / 1e3

    def __call__(self):
        self.t += self.tick
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


# ---------------------------------------------------------------- spec DSL

def test_parse_spec_grammar():
    cs = parse_spec("nan_decode@iter=7:slot=2,corrupt_blob@rid=r3,"
                    "stall@iter=12:n=3")
    assert [c.kind for c in cs] == ["nan_decode", "corrupt_blob", "stall"]
    assert cs[0].params["iter"] == 7 and cs[0].params["slot"] == 2
    assert cs[0].params["n"] == 1          # default budget
    assert cs[1].params["rid"] == 3        # rNN form
    assert cs[2].params["n"] == 3
    assert parse_spec("") == []


@pytest.mark.parametrize("bad", [
    "meteor@iter=1",                 # unknown kind
    "nan_decode",                    # missing required iter=
    "stall@iter",                    # malformed param (no '=')
    "nan_decode@iter=x",             # non-integer value
])
def test_parse_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_spec(bad)


def test_clause_budgets_and_stall_window():
    plan = FaultPlan.from_spec("nan_decode@iter=2:slot=1,stall@iter=5:n=2")
    assert plan.nan_decode_slots(1) == []      # before the trigger
    assert plan.nan_decode_slots(3) == [1]     # fires (>= iter)
    assert plan.nan_decode_slots(4) == []      # budget n=1 exhausted
    assert not plan.stalled(4)
    assert plan.stalled(5) and plan.stalled(6)
    assert not plan.stalled(7)                 # window [5, 5+2)
    assert FaultPlan.from_spec("stall@iter=0").stalled(10 ** 6)  # n=-1


def test_corrupt_blob_deterministic_and_copying():
    blob = {"a": np.arange(16, dtype=np.float32),
            "b": np.ones(4, np.int32)}
    keep = {k: v.copy() for k, v in blob.items()}
    p1 = FaultPlan.from_spec("corrupt_blob@rid=r5", seed=11)
    p2 = FaultPlan.from_spec("corrupt_blob@rid=r5", seed=11)
    out1, out2 = p1.corrupt_blob(5, blob), p2.corrupt_blob(5, blob)
    # same seed + rid -> same flipped byte; the input blob is untouched
    diff = [k for k in blob if not np.array_equal(out1[k], blob[k])]
    assert len(diff) == 1
    np.testing.assert_array_equal(out1[diff[0]], out2[diff[0]])
    for k in blob:
        np.testing.assert_array_equal(blob[k], keep[k])
    # a non-matching rid passes through untouched (and spends no budget)
    assert p1.corrupt_blob(6, blob) is blob


def test_poison_slot_hits_one_row_only():
    cfg, _ = _setup("hybrid")
    cache = init_lm_cache(cfg, 3, 32)
    poisoned = poison_slot(cache, 1)
    for seg in poisoned["segments"]:
        for leaf in jax.tree_util.tree_leaves(seg):
            if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
                assert bool(jnp.all(jnp.isnan(leaf[:, 1])))
                assert bool(jnp.all(jnp.isfinite(leaf[:, 0])))
                assert bool(jnp.all(jnp.isfinite(leaf[:, 2])))
    np.testing.assert_array_equal(np.asarray(poisoned["pos"]),
                                  np.asarray(cache["pos"]))


# ----------------------------------------------------------- blob integrity

def _slot_blob(arch="hybrid"):
    cfg, _ = _setup(arch)
    cache = init_lm_cache(cfg, 2, 32)
    rng = np.random.default_rng(0)
    segs = [jax.tree_util.tree_map(
        lambda l: jnp.asarray(rng.normal(size=l.shape), l.dtype)
        if jnp.issubdtype(l.dtype, jnp.floating) else l, seg)
        for seg in cache["segments"]]
    cache = {"segments": segs, "pos": cache["pos"]}
    return cache, offload_slot(cache, 0)


def test_blob_roundtrip_validates():
    cache, blob = _slot_blob()
    assert BLOB_META_KEY in blob
    restored = restore_slot(cache, blob, 1)      # no raise
    a = jax.tree_util.tree_leaves(restored["segments"])
    b = jax.tree_util.tree_leaves(cache["segments"])
    assert any(not np.array_equal(np.asarray(x[:, 1]), np.asarray(y[:, 1]))
               or True for x, y in zip(a, b))    # structural smoke


def test_blob_bitflip_raises_cache_corruption_naming_key():
    cache, blob = _slot_blob()
    key = sorted(k for k, v in blob.items()
                 if isinstance(v, np.ndarray)
                 and v.dtype.kind == "f" and v.nbytes)[0]
    arr = blob[key].copy()
    arr.view(np.uint8).reshape(-1)[3] ^= np.uint8(4)
    blob[key] = arr
    with pytest.raises(CacheCorruption) as ei:
        restore_slot(cache, blob, 1, rid=7)
    msg = str(ei.value)
    assert "crc32" in msg and key in msg and "rid=7" in msg


def test_blob_keyset_diff_in_message():
    cache, blob = _slot_blob()
    victim = next(k for k in blob if k != BLOB_META_KEY)
    del blob[victim]
    blob["bogus/leaf"] = np.zeros(3, np.float32)
    with pytest.raises(CacheCorruption) as ei:
        restore_slot(cache, blob, 1)
    msg = str(ei.value)
    assert victim in msg and "bogus/leaf" in msg
    assert "missing=" in msg and "extra=" in msg


def test_blob_schema_mismatch_raises():
    cache, blob = _slot_blob()
    key = next(k for k, v in blob.items()
               if isinstance(v, np.ndarray) and v.dtype.kind == "f")
    blob[key] = blob[key].astype(np.float64)     # dtype drift
    with pytest.raises(CacheCorruption) as ei:
        validate_blob(blob, [k for k in blob if k != BLOB_META_KEY])
    assert "schema" in str(ei.value) and key in str(ei.value)


def test_legacy_blob_without_meta_still_restores():
    cache, blob = _slot_blob()
    del blob[BLOB_META_KEY]
    restore_slot(cache, blob, 1)                 # key-set check only


# -------------------------------------------------------- submit validation

def test_submit_rejects_bad_prompts():
    cfg, params = _setup("hybrid")
    eng = ServingEngine(cfg, params, slots=1, max_seq=32)
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(rid=0, prompt=np.array([1, cfg.vocab_size],
                                                  np.int32), max_new=2))
    with pytest.raises(ValueError, match="vocab"):
        eng.submit(Request(rid=1, prompt=np.array([-1, 2], np.int32),
                           max_new=2))
    with pytest.raises(ValueError, match="integer"):
        eng.submit(Request(rid=2, prompt=np.array([1.5, 2.0]), max_new=2))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(rid=3, prompt=np.array([], np.int32), max_new=2))
    assert not eng.queue                # nothing half-admitted


# ------------------------------------------------- divergence + co-batching

def _fault_matrix(arch):
    """The acceptance matrix for one arch under the active kernel backend:
    (1) fault-free reference; (2) transient NaN -> checkpoint replay
    recovers bit-identically; (3) NaN without checkpoints -> structured
    DivergenceDetected; (4) NaN mid-prefill -> row quarantined.  In every
    faulted run the co-batched healthy request matches the reference
    bit-for-bit."""
    ref, _ = _run(arch, None)
    assert all(r.status == "ok" for r in ref.values())

    # transient decode NaN + checkpoint replay -> full recovery.  rid=0
    # (prompt len 9, two chunks) emits at iter 1 into slot 0 and decodes
    # through iter 2 — the poison must land while the slot is live.
    plan = FaultPlan.from_spec("nan_decode@iter=2:slot=0")
    rec, eng = _run(arch, plan, checkpoint_every=2)
    assert eng.stats["divergences"] == 1 and eng.stats["replays"] == 1
    for rid, r in rec.items():
        assert r.status == "ok" and r.error is None
        assert r.out == ref[rid].out, f"rid={rid} not bit-identical"

    # decode NaN with checkpointing disabled -> structured failure
    plan = FaultPlan.from_spec("nan_decode@iter=2:slot=0")
    res, eng = _run(arch, plan, checkpoint_every=0)
    victims = [r for r in res.values() if r.status == "failed"]
    assert len(victims) == 1
    assert isinstance(victims[0].error, DivergenceDetected)
    assert f"rid={victims[0].rid}" in str(victims[0].error)
    for r in res.values():
        if r.status == "ok":
            assert r.out == ref[r.rid].out
    assert eng.stats["failures"] == 1

    # prefill NaN -> the poisoned row is quarantined out of its group
    plan = FaultPlan.from_spec("nan_prefill@chunk=0:row=0")
    res, eng = _run(arch, plan)
    assert res[0].status == "failed"
    assert isinstance(res[0].error, DivergenceDetected)
    assert not res[0].out                      # never emitted
    assert res[1].status == "ok" and res[1].out == ref[1].out


@pytest.mark.slow
def test_fault_matrix_hybrid_ref():
    _fault_matrix("hybrid")


@pytest.mark.slow
@pytest.mark.parametrize("arch,backend", [
    ("dense", "ref"), ("mamba2", "ref"),
    ("dense", "interpret"), ("mamba2", "interpret"),
    ("hybrid", "interpret"),
])
def test_fault_matrix_sweep(arch, backend):
    with dispatch.use_backend(backend):
        _fault_matrix(arch)


@pytest.mark.slow
def test_corrupt_preemption_blob_fails_only_victim():
    """slots=1 forces preemption of rid=0; its offload blob is bit-flipped
    so the restore must fail rid=0 with CacheCorruption while rid=1 (the
    request that triggered the starvation) completes bit-identically to
    its fault-free run."""
    # checkpointing off so rid=0's ONLY offload is the preemption blob
    # (the n=1 corruption budget must not be spent on a checkpoint)
    ref, _ = _run("hybrid", None, n_req=2, slots=1, preempt_after=2,
                  max_new=12, checkpoint_every=0)
    plan = FaultPlan.from_spec("corrupt_blob@rid=r0", seed=5)
    res, eng = _run("hybrid", plan, n_req=2, slots=1, preempt_after=2,
                    max_new=12, checkpoint_every=0)
    assert eng.stats["preemptions"] >= 1
    assert res[0].status == "failed"
    assert isinstance(res[0].error, CacheCorruption)
    assert res[1].status == "ok" and res[1].out == ref[1].out


# ----------------------------------------------------- deadlines + watchdog

def test_deadline_expires_while_queued():
    cfg, params = _setup("hybrid")
    clock = FakeClock()
    eng = ServingEngine(cfg, params, slots=1, max_seq=48, decode_block=4,
                        clock=clock)
    p0, p1 = _prompts(cfg)
    eng.submit(Request(rid=0, prompt=p0, max_new=4))
    eng.submit(Request(rid=1, prompt=p1, max_new=4, deadline_ms=5.0))
    clock.advance_ms(10)                      # r1's TTL burns in the queue
    done = {r.rid: r for r in eng.run(max_iters=100)}
    assert done[0].status == "ok"
    assert done[1].status == "timed_out" and not done[1].out
    assert isinstance(done[1].error, DeadlineExceeded)
    assert eng.stats["timeouts"] == 1


def test_deadline_expires_mid_decode():
    cfg, params = _setup("hybrid")
    clock = FakeClock(tick_ms=1.0)            # 1ms per engine clock read
    eng = ServingEngine(cfg, params, slots=1, max_seq=256, decode_block=4,
                        checkpoint_every=0, clock=clock)
    eng.submit(Request(rid=0, prompt=_prompts(cfg)[0], max_new=200,
                       deadline_ms=40.0))
    (req,) = eng.run(max_iters=300)
    assert req.status == "timed_out"
    assert isinstance(req.error, DeadlineExceeded)
    assert req.out and len(req.out) < 200     # made progress, then expired


def test_deadline_admission_reject_uses_latency_model():
    cfg, params = _setup("hybrid")
    eng = ServingEngine(cfg, params, slots=1, max_seq=48, decode_block=4,
                        clock=FakeClock())
    # measured: 50ms / token (steady decode sample; telemetry is the only
    # cost model — the legacy scalar EWMA path is gone)
    eng.telemetry.record_latency("decode", None, 50.0)
    p0, p1 = _prompts(cfg)
    eng.submit(Request(rid=0, prompt=p0, max_new=8, deadline_ms=10.0))
    eng.submit(Request(rid=1, prompt=p1, max_new=8))
    done = {r.rid: r for r in eng.run(max_iters=100)}
    assert done[0].status == "cancelled"      # 8 * 50ms >> 10ms budget
    assert "admission reject" in str(done[0].error)
    assert done[1].status == "ok"


def test_preemption_picks_slackest_slot():
    """With a queued request starving, the deadline-less live slot
    (infinite slack) must be the preemption victim, not the slot
    running under a deadline."""
    cfg, params = _setup("hybrid")
    p = _prompts(cfg, lens=(6, 6, 6))
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, decode_block=2,
                        preempt_after=1)
    eng.submit(Request(rid=0, prompt=p[0], max_new=6, deadline_ms=60_000.0))
    eng.submit(Request(rid=1, prompt=p[1], max_new=24))
    eng.submit(Request(rid=2, prompt=p[2], max_new=4))
    done = {r.rid: r for r in eng.run(max_iters=200)}
    assert all(r.status == "ok" for r in done.values())
    assert done[0].preemptions == 0
    assert done[1].preemptions >= 1
    assert eng.stats["preemptions"] >= 1


def test_watchdog_trips_on_frozen_prefill():
    plan = FaultPlan.from_spec("stall@iter=0")     # freeze prefill forever
    res, eng = _run("hybrid", plan, stall_after=4)
    assert eng.stats["watchdog_trips"] >= 1
    for r in res.values():
        assert r.status == "failed"
        assert isinstance(r.error, SlotStalled)
        assert "no progress" in str(r.error)


def test_run_max_iters_escape_hatch():
    plan = FaultPlan.from_spec("stall@iter=0")
    res, eng = _run("hybrid", plan, stall_after=10 ** 6)   # watchdog muted
    assert eng.stats["iters"] <= 201
    for r in res.values():
        assert r.status == "cancelled"
        assert isinstance(r.error, SlotStalled)
        assert "max_iters" in str(r.error)


def test_error_hierarchy_and_rid_prefix():
    for exc in (DeadlineExceeded, DivergenceDetected, SlotStalled,
                CacheCorruption):
        assert issubclass(exc, RequestError)
    e = CacheCorruption("bad payload", rid=3, key="segments/0/k")
    assert str(e).startswith("rid=3: ")
    assert "segments/0/k" in str(e)
    assert str(DivergenceDetected("nan burst")) == "nan burst"
