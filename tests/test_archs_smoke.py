"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward and one train step on CPU; output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, reduced
from repro.core.registry import get
from repro.core.workload import AUDIO_FEAT_DIM, realize
from repro.models import init_lm_params, lm_forward
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

BATCH, SEQ = 2, 32


def _inputs(cfg, train=True):
    key = jax.random.PRNGKey(0)
    if cfg.frontend == "audio":
        d = {"features": jax.random.normal(
            key, (BATCH, SEQ, cfg.frontend_feature_dim), jnp.bfloat16)}
    elif cfg.frontend == "vision":
        d = {"tokens": jax.random.randint(key, (BATCH, SEQ - 8), 0,
                                          cfg.vocab_size, jnp.int32),
             "features": jax.random.normal(
                 key, (BATCH, 8, cfg.frontend_feature_dim), jnp.bfloat16)}
    else:
        d = {"tokens": jax.random.randint(key, (BATCH, SEQ), 0,
                                          cfg.vocab_size, jnp.int32)}
    if train:
        d["labels"] = jax.random.randint(key, (BATCH, SEQ), 0,
                                         cfg.vocab_size, jnp.int32)
    return d


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward(arch):
    cfg = reduced(get(arch))
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    inputs = _inputs(cfg, train=False)
    logits = jax.jit(lambda p, i: lm_forward(cfg, p, i, train=False))(
        params, inputs)
    assert logits.shape[0] == BATCH
    assert logits.shape[-1] == cfg.padded_vocab
    arr = np.asarray(logits[..., :cfg.vocab_size], np.float32)
    assert not np.isnan(arr).any(), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", [
    # the two heaviest reduced configs train only in the slow sweep
    # (scripts/verify.sh); their forward smokes stay in tier-1
    pytest.param(a, marks=pytest.mark.slow)
    if a in ("zamba2-2.7b", "gemma3-1b", "llama4-maverick-400b-a17b")
    else a
    for a in ASSIGNED])
def test_reduced_train_step(arch):
    cfg = reduced(get(arch))
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    opt = OptConfig(lr=1e-3)
    state = init_opt_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _inputs(cfg, train=True)
    new_params, new_state, metrics = step(params, state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert int(new_state["step"]) == 1
    # params actually changed
    d0 = jax.tree_util.tree_leaves(params)[0]
    d1 = jax.tree_util.tree_leaves(new_params)[0]
    assert not np.array_equal(np.asarray(d0, np.float32),
                              np.asarray(d1, np.float32))
