"""Engine regression: per-slot positions + batched admission.

The old engine shared one ``pos`` counter (``pos.max()``) across slots, so a
slot admitted later attended over garbage cache rows.  With the per-slot
``pos`` vector every request must decode exactly the tokens it would get
running alone."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_params
from repro.serving.engine import Request, ServingEngine, greedy_generate

KEY = jax.random.PRNGKey(0)


def _cfg():
    return ModelConfig(name="hyb", family="hybrid", n_layers=4, d_model=64,
                       d_ff=0, vocab_size=97,
                       ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                       layer_pattern=("mamba2", "mamba2+shared"),
                       shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                              head_dim=16),
                       shared_attn_d_ff=128, vocab_pad_multiple=16)


@pytest.mark.slow
def test_late_admitted_slots_match_solo_decode():
    """5 requests through 2 slots: the last three are admitted mid-flight at
    positions different from the resident slots. Outputs must equal a
    batch-1 greedy_generate of the same prompt (the shared-pos engine
    failed this for every late admission).  Slow sweep: the head-of-line
    and preemption tests in test_prefill_engine keep per-slot-pos parity
    covered in tier-1."""
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (9, 17, 12, 9, 23)]
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, decode_block=4)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=10))
    done = {r.rid: r.out for r in eng.run()}
    assert len(done) == len(prompts)
    for i, p in enumerate(prompts):
        solo, _ = greedy_generate(cfg, params,
                                  {"tokens": jnp.asarray(p[None])},
                                  max_seq=64, gen_len=10)
        np.testing.assert_array_equal(
            np.asarray(done[i][:10]), np.asarray(solo[0]),
            err_msg=f"rid={i} diverged from solo decode")


@pytest.mark.slow
def test_admission_reuses_templates(monkeypatch):
    """Admission must not allocate a fresh full cache per request: the
    chunked-prefill group templates are bounded by the retained batch
    sizes {1, slots}, however many requests flow through."""
    import repro.serving.prefill as prefill_mod
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=2, max_seq=48, decode_block=4)
    calls = []
    real_init = prefill_mod.init_lm_cache
    monkeypatch.setattr(prefill_mod, "init_lm_cache",
                        lambda *a, **kw: (calls.append(a), real_init(*a, **kw))[1])
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(2, cfg.vocab_size,
                                               8).astype(np.int32),
                           max_new=4))
    eng.run()
    assert len(eng.finished) == 6
    # 6 admissions, but at most one allocation per retained template size
    assert len(calls) <= 2, f"per-admission allocation crept back: {calls}"
    # and the template objects are literally reused
    ch = eng._chunked_prefill
    for batch in ch._templates:
        assert ch._template(batch) is ch._template(batch)


def test_max_new_respected_with_blocks():
    """decode_block > max_new must not over-emit."""
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=2, max_seq=48, decode_block=8)
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(2, cfg.vocab_size,
                                               6).astype(np.int32),
                           max_new=3))
    done = eng.run()
    assert all(len(r.out) == 3 for r in done)
