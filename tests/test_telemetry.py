"""Telemetry layer: the per-(phase, KV-bucket) latency model, span
traces, and the compile-sample regressions it exists to fix.

Regression coverage (ISSUE 7):

* a decode burst entering a FRESH KV bucket pays XLA trace+compile; its
  latency sample must land in the segregated compile record and never
  move the steady-state EWMA feeding deadline admission (the engine used
  to compute ``fresh_compile`` and then not gate the sample with it);
* ragged final prefill chunks used to divide by the padded chunk size,
  deflating the per-token estimate used for admission;
* engine timing mixed ``time.perf_counter()`` with the injectable
  ``clock`` — all timestamps must now come from one clock, so
  fake-clock tests see consistent EWMAs and span traces.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.models.lm import init_lm_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.telemetry import (TRACE_SCHEMA_VERSION, Telemetry,
                                     TelemetryTable, operator_costs,
                                     read_trace)

KEY = jax.random.PRNGKey(0)


def _cfg():
    return ModelConfig(name="hyb", family="hybrid", n_layers=4, d_model=64,
                       d_ff=0, vocab_size=97,
                       ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                       layer_pattern=("mamba2", "mamba2+shared"),
                       shared_attn=AttnConfig(n_heads=4, n_kv_heads=4,
                                              head_dim=16),
                       shared_attn_d_ff=128, vocab_pad_multiple=16)


class FakeClock:
    """Injectable engine clock: advances ``tick_ms`` on every read."""

    def __init__(self, tick_ms=0.0):
        self.t = 0.0
        self.tick = tick_ms / 1e3

    def __call__(self):
        self.t += self.tick
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1e3


def _prompt(cfg, n, seed=3):
    rng = np.random.default_rng(seed)
    return rng.integers(2, cfg.vocab_size, int(n)).astype(np.int32)


# ------------------------------------------------------------ unit layer

def test_compile_samples_segregated_from_steady():
    tel = Telemetry(clock=lambda: 0.0, trace_path="")
    tel.record_latency("decode", 128, 500.0, compiled=True)   # compile spike
    tel.record_latency("decode", 128, 1.0)
    tel.record_latency("decode", 128, 3.0)
    # steady estimate sees ONLY the steady samples
    assert tel.estimate("decode", 128) == pytest.approx(
        0.25 * 3.0 + 0.75 * 1.0)
    snap = tel.latency_snapshot()["table"]["decode@128"]
    assert snap["compile"]["count"] == 1
    assert snap["compile"]["max_ms"] == 500.0
    assert snap["steady"]["count"] == 2
    assert snap["steady"]["min_ms"] == 1.0 and snap["steady"]["max_ms"] == 3.0


def test_estimate_falls_back_bucket_to_global_to_none():
    tel = Telemetry(clock=lambda: 0.0, trace_path="")
    assert tel.estimate("decode", 128) is None
    tel.record_latency("decode", 128, 2.0)
    # unmeasured bucket falls back to the phase-global steady record
    assert tel.estimate("decode", 512) == pytest.approx(2.0)
    # a phase with only compile samples still has no steady estimate
    tel.record_latency("prefill", 128, 99.0, compiled=True)
    assert tel.estimate("prefill", 128) is None


def test_operator_costs_reports_kernel_family_shares():
    fn = jax.jit(lambda a, b: jnp.tanh(jnp.dot(a, b)))
    x = jnp.ones((32, 32), jnp.float32)
    costs = operator_costs(fn.lower(x, x).compile())
    assert costs["flops"] > 0
    assert "gemm" in costs["by_class"]
    assert costs["by_class"]["gemm"]["flop_share"] > 0.5
    total = sum(c["flop_share"] for c in costs["by_class"].values())
    assert total == pytest.approx(1.0, abs=1e-6)


# ---------------------------------------------------------- engine layer

def test_fresh_bucket_burst_tagged_compile_not_steady():
    """Decode climbs the bucket ladder (128 -> 256): exactly one compile
    sample per bucket key, everything else steady — the ladder climb no
    longer moves the steady-state EWMA that admission relies on."""
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=1, max_seq=320, decode_block=8,
                        chunk_size=16, clock=FakeClock(tick_ms=1.0))
    eng.submit(Request(rid=0, prompt=_prompt(cfg, 16), max_new=160))
    (req,) = eng.run(max_iters=500)
    assert req.status == "ok" and len(req.out) == 160
    assert {128, 256} <= eng.buckets_used
    full = eng.telemetry.latency_snapshot()
    # the snapshot names its schema and arch explicitly (ISSUE 8)
    assert full["version"] == TRACE_SCHEMA_VERSION
    assert full["arch"] == "hyb"
    snap = full["table"]
    total_steady = 0
    for bucket in (128, 256):
        rec = snap[f"decode@{bucket}"]
        assert rec["compile"]["count"] == 1, (bucket, rec)
        assert rec["steady"]["count"] >= 1, (bucket, rec)
        total_steady += rec["steady"]["count"]
    # the phase-global aggregate is exactly the per-bucket records summed
    assert snap["decode@*"]["compile"]["count"] == 2
    assert snap["decode@*"]["steady"]["count"] == total_steady
    assert eng.telemetry.estimate("decode", None) > 0.0


def test_admission_estimate_ignores_compile_spikes():
    """A 500ms compile sample next to 1ms steady samples must not reject
    a feasible request — the spurious-timeout regression."""
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=1, max_seq=320, decode_block=8,
                        clock=FakeClock())
    eng.telemetry.record_latency("decode", 128, 500.0, compiled=True)
    eng.telemetry.record_latency("decode", 128, 1.0)
    eng.telemetry.record_latency("prefill", 128, 0.5)
    req = Request(rid=0, prompt=_prompt(cfg, 8), max_new=16,
                  deadline_ms=100.0)
    est = eng._admission_estimate_ms(req)
    # 8 * 0.5 + 16 * 1.0 = 20ms, comfortably inside the 100ms budget;
    # had the compile spike fed steady state this would be > 2000ms
    assert est == pytest.approx(8 * 0.5 + 16 * 1.0)
    eng.submit(req)
    done = {r.rid: r for r in eng.run(max_iters=200)}
    assert done[0].status == "ok"


def test_ragged_final_chunk_divides_by_valid_tokens():
    """Prompt of 12 tokens through chunk_size=8: the final chunk carries
    4 valid tokens.  With a 1ms-per-clock-read fake clock every chunk
    measures 1ms, so the steady per-token estimate must be 1/4 ms (valid
    tokens), not 1/8 ms (padded chunk size)."""
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=1, max_seq=64, decode_block=4,
                        chunk_size=8, clock=FakeClock(tick_ms=1.0))
    eng.submit(Request(rid=0, prompt=_prompt(cfg, 12), max_new=4))
    (req,) = eng.run(max_iters=100)
    assert req.status == "ok"
    # chunk 0 (8 valid) is the fresh-compile sample; chunk 1 (4 valid) is
    # the only steady sample: 1ms / 4 tokens
    assert eng.telemetry.estimate("prefill", None) == pytest.approx(0.25)
    snap = eng.telemetry.latency_snapshot()["table"]
    # exactly one concrete prefill bucket key (max_seq=64 caps the ladder)
    (key,) = [k for k in snap
              if k.startswith("prefill@") and not k.endswith("@*")]
    rec = snap[key]
    assert rec["compile"]["count"] == 1
    assert rec["compile"]["min_ms"] == pytest.approx(1.0 / 8)
    assert rec["steady"]["count"] == 1
    assert rec["steady"]["ewma_ms"] == pytest.approx(0.25)


def test_engine_timing_single_clock_source():
    """Every telemetry timestamp must come from the injected clock: with
    a fake clock starting at 0, a perf_counter() leak would show up as a
    timestamp ~ hours-to-years ahead of the fake time base."""
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    clock = FakeClock(tick_ms=1.0)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, decode_block=4,
                        chunk_size=8, clock=clock)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=_prompt(cfg, 9 + i), max_new=6))
    eng.run(max_iters=200)
    assert len(eng.telemetry.finished_spans) == 2
    for span in eng.telemetry.finished_spans:
        assert 0.0 < span["submit_t"] <= span["end_t"] <= clock.t
        for ev in span["events"]:
            assert span["submit_t"] <= ev["t"] <= clock.t


def test_trace_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64, decode_block=4,
                        chunk_size=8, trace_path=path)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=_prompt(cfg, 7 + 3 * i), max_new=5))
    eng.run(max_iters=300)
    spans = read_trace(path)
    assert sorted(s["rid"] for s in spans) == [0, 1, 2]
    for s in spans:
        # every line names its schema + arch (stale traces are rejectable)
        assert s["version"] == TRACE_SCHEMA_VERSION
        assert s["arch"] == "hyb"
        assert s["status"] == "ok"
        assert s["tokens_out"] == 5
        kinds = [e["kind"] for e in s["events"]]
        assert "prefill" in kinds and "decode" in kinds
        prefill = [e for e in s["events"] if e["kind"] == "prefill"]
        assert sum(e["tokens"] for e in prefill) == s["prompt_len"]
        decode = [e for e in s["events"] if e["kind"] == "decode"]
        # the first output token is emitted by the final prefill chunk,
        # so decode bursts account for max_new - 1 of the 5 tokens
        assert sum(e["tokens"] for e in decode) == 4
        for e in prefill + decode:
            assert e["bucket"] > 0
        # bursts coalesce: the span scales with bucket climbs, not tokens
        assert len(decode) <= 4
    # each line is standalone JSON (the JSONL contract)
    with open(path) as f:
        for line in f:
            json.loads(line)


def test_span_records_preemption_and_terminal_error():
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=1, max_seq=64, decode_block=2,
                        preempt_after=1, clock=FakeClock())
    p = _prompt(cfg, 6)
    eng.submit(Request(rid=0, prompt=p, max_new=24))
    eng.submit(Request(rid=1, prompt=_prompt(cfg, 6, seed=4), max_new=4))
    eng.run(max_iters=300)
    spans = {s["rid"]: s for s in eng.telemetry.finished_spans}
    assert spans[0]["preemptions"] >= 1
    assert any(e["kind"] == "preempt" for e in spans[0]["events"])
    assert any(e["kind"] == "restore" for e in spans[0]["events"])
    # a failed request carries its structured error on the span
    eng2 = ServingEngine(cfg, params, slots=1, max_seq=64, decode_block=4,
                         clock=FakeClock())
    bad = Request(rid=7, prompt=p, max_new=4, deadline_ms=5.0)
    eng2.submit(bad)
    eng2._clock.advance_ms(50)
    eng2.run(max_iters=50)
    (span,) = eng2.telemetry.finished_spans
    assert span["status"] == "timed_out"
    assert "deadline" in span["error"]


def test_read_trace_rejects_stale_schema_version(tmp_path):
    path = tmp_path / "trace.jsonl"
    path.write_text(json.dumps({"version": 1, "rid": 0, "events": []})
                    + "\n")
    with pytest.raises(ValueError, match="schema version 1"):
        read_trace(str(path))


# ----------------------------------------------- arch keying + warm start

def test_latency_table_never_mixes_archs():
    """Two engines (archs) over ONE shared table: rungs recorded under
    one arch are invisible to the other — the cross-arch fallback bug
    the per-arch key exists to fix."""
    table = TelemetryTable()
    a = Telemetry(clock=lambda: 0.0, trace_path="", arch="ssm-a",
                  table=table)
    b = Telemetry(clock=lambda: 0.0, trace_path="", arch="hyb-b",
                  table=table)
    a.record_latency("decode", 128, 2.0)
    a.record_latency("decode", 512, 8.0)
    assert a.estimate("decode", 128) == pytest.approx(2.0)
    # arch b must not fall back into arch a's rungs OR its global record
    assert b.estimate("decode", 128) is None
    assert b.estimate("decode", 4096) is None
    b.record_latency("decode", 128, 5.0)
    assert b.estimate("decode", 128) == pytest.approx(5.0)
    assert a.estimate("decode", 128) == pytest.approx(2.0)
    assert table.archs() == ["hyb-b", "ssm-a"]
    # each front snapshots only its own slice
    assert set(a.latency_snapshot()["table"]) == {"decode@128", "decode@512",
                                                  "decode@*"}
    assert a.latency_snapshot()["arch"] == "ssm-a"


def test_warmstart_roundtrip_table(tmp_path):
    path = str(tmp_path / "warm.json")
    tel = Telemetry(clock=lambda: 0.0, trace_path="", arch="hyb")
    tel.record_latency("decode", 128, 500.0, compiled=True)
    tel.record_latency("decode", 128, 2.0)
    tel.record_latency("prefill", 128, 0.5)
    assert tel.save_warmstart(path) == path
    warm = Telemetry(clock=lambda: 0.0, trace_path="", arch="hyb",
                     warmstart_path=path)
    assert warm.warmstart_loaded
    # warm estimates are the persisted STEADY values — the 500ms compile
    # spike rides along in the compile record but never feeds estimates
    assert warm.estimate("decode", 128) == pytest.approx(2.0)
    assert warm.estimate("prefill", 128) == pytest.approx(0.5)
    rec = warm.latency_snapshot()["table"]["decode@128"]
    assert rec["compile"]["count"] == 1 and rec["compile"]["max_ms"] == 500.0


def test_warmstart_rejects_corrupt_and_stale_blobs(tmp_path, caplog):
    import logging
    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    with caplog.at_level(logging.WARNING, "repro.serving.telemetry"):
        cold = Telemetry(clock=lambda: 0.0, trace_path="",
                         warmstart_path=str(garbage))
    assert not cold.warmstart_loaded
    assert cold.estimate("decode", 128) is None
    assert any("warm-start rejected" in r.message for r in caplog.records)
    caplog.clear()
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"version": 99, "archs": {}}))
    with caplog.at_level(logging.WARNING, "repro.serving.telemetry"):
        cold = Telemetry(clock=lambda: 0.0, trace_path="",
                         warmstart_path=str(stale))
    assert not cold.warmstart_loaded
    assert any("version" in r.message for r in caplog.records)
    # a structurally broken table body is rejected the same way
    broken = tmp_path / "broken.json"
    broken.write_text(json.dumps({"version": 1, "archs": {"hyb": 7}}))
    with pytest.raises(ValueError):
        TelemetryTable().load(str(broken))


def test_warm_started_engine_first_admission_uses_persisted_estimate(
        tmp_path):
    """The acceptance path: engine run 1 persists its measured latency
    model; engine 2 (fresh process stand-in, fake clock, ZERO dispatches)
    must admission-estimate from the persisted steady records — and
    reject an infeasible deadline before paying any compile."""
    path = str(tmp_path / "warm.json")
    cfg = _cfg()
    params = init_lm_params(cfg, KEY)
    eng = ServingEngine(cfg, params, slots=1, max_seq=128, decode_block=4,
                        chunk_size=16, clock=FakeClock(tick_ms=1.0),
                        warmstart_path=path)
    # 3 prefill chunks: the first is the segregated compile sample, the
    # rest give the persisted prefill record STEADY samples to warm from
    eng.submit(Request(rid=0, prompt=_prompt(cfg, 40), max_new=24))
    (req,) = eng.run(max_iters=200)    # run() persists in its finally
    assert req.status == "ok"
    import os
    assert os.path.exists(path)

    # frozen clock: zero elapsed time, so the doomed request below can
    # only die through the admission ESTIMATE, never by TTL expiry
    eng2 = ServingEngine(cfg, params, slots=1, max_seq=128, decode_block=4,
                         chunk_size=16, clock=FakeClock(),
                         warmstart_path=path)
    assert eng2.telemetry.warmstart_loaded
    # first-burst admission estimate exists BEFORE any dispatch, equals
    # the persisted steady model (zero local dispatches have happened)
    assert eng2.stats["decode_tokens"] == 0
    probe = Request(rid=1, prompt=_prompt(cfg, 16), max_new=24)
    est = eng2._admission_estimate_ms(probe)
    assert est is not None and est > 0.0
    ptok = eng2.telemetry.estimate("prefill", 128)
    tpot = eng2.telemetry.estimate("decode", 128)
    assert est == pytest.approx(16 * ptok + 24 * tpot)
    # an infeasible deadline is rejected at admission, pre-dispatch
    doomed = Request(rid=2, prompt=_prompt(cfg, 16), max_new=24,
                     deadline_ms=est / 100.0)
    eng2.submit(doomed)
    eng2.run(max_iters=50)
    assert doomed.status == "cancelled"
    assert "admission reject" in str(doomed.error)
    assert eng2.stats["decode_tokens"] == 0    # rejected before any burst
