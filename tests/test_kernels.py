"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attn_decode.kernel import decode_attention_pallas
from repro.kernels.attn_decode.ref import decode_attention_ref
from repro.kernels.conv1d.kernel import causal_conv1d_pallas
from repro.kernels.conv1d.ref import causal_conv1d_ref
from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_ref, ring_kv_positions
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_sequential

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# --------------------------------------------------------------------- SSD
# the first two shapes are the tier-1 parity smoke; the larger sweep points
# run under REPRO_RUN_SLOW=1 (scripts/verify.sh)
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    pytest.param(1, 32, 2, 8, 1, 8, 8, marks=pytest.mark.slow),
    (2, 64, 4, 16, 2, 16, 16),
    pytest.param(1, 128, 8, 64, 1, 32, 32, marks=pytest.mark.slow),
    pytest.param(2, 96, 4, 32, 4, 64, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel(b, s, h, p, g, n, chunk, dtype):
    ks = jax.random.split(KEY, 7)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n), dtype)
    Cm = jax.random.normal(ks[4], (b, s, g, n), dtype)
    D = jax.random.normal(ks[5], (h,))
    h0 = jax.random.normal(ks[6], (b, h, p, n), jnp.float32)
    y_ref, h_ref = ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk,
                                   initial_state=h0)
    y_k, h_k = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                          initial_state=h0, interpret=True)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    assert float(jnp.abs(y_ref.astype(jnp.float32)
                         - y_k.astype(jnp.float32)).max()) / scale < _tol(dtype)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_ssd_kernel_matches_sequential_oracle():
    b, s, h, p, g, n = 1, 64, 2, 16, 1, 16
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    D = jax.random.normal(ks[5], (h,))
    y_seq, h_seq = ssd_sequential(x, dt, A, Bm, Cm, D)
    y_k, h_k = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_seq),
                               rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ conv1d
@pytest.mark.parametrize("b,s,c,k", [
    (1, 64, 32, 4),
    pytest.param(2, 128, 64, 4, marks=pytest.mark.slow),
    pytest.param(1, 256, 128, 2, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_kernel(b, s, c, k, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, c), dtype)
    w = jax.random.normal(ks[1], (c, k))
    bias = jax.random.normal(ks[2], (c,))
    st = jax.random.normal(ks[3], (b, k - 1, c), dtype)
    y_ref, s_ref = causal_conv1d_ref(x, w, bias, st)
    y_k, s_k = causal_conv1d_pallas(x, w, bias, initial_state=st,
                                    block_seq=min(64, s), block_ch=min(32, c),
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(s_k, np.float32),
                               np.asarray(s_ref, np.float32), rtol=1e-6)


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel(causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 8, 80, 32), dtype)
    k = jax.random.normal(ks[1], (2, 2, 80, 32), dtype)
    v = jax.random.normal(ks[2], (2, 2, 80, 32), dtype)
    o_ref = attention_ref(q, k, v, causal=causal, window=window)
    o_k = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32, interpret=True)
    scale = float(jnp.abs(o_ref.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(o_ref.astype(jnp.float32)
                        - o_k.astype(jnp.float32)).max()) / scale
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_q_offset(dtype):
    """Chunked-prefill shape: a short query chunk at per-row offsets
    against a long KV prefix (offset causal mask, SMEM offsets)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (3, 4, 16, 32), dtype)
    k = jax.random.normal(ks[1], (3, 2, 80, 32), dtype)
    v = jax.random.normal(ks[2], (3, 2, 80, 32), dtype)
    off = jnp.asarray([0, 13, 64], jnp.int32)
    o_ref = attention_ref(q, k, v, causal=True, q_offset=off)
    o_k = flash_attention_pallas(q, k, v, causal=True, q_offset=off,
                                 block_q=8, block_k=32, interpret=True)
    scale = float(jnp.abs(o_ref.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(o_ref.astype(jnp.float32)
                        - o_k.astype(jnp.float32)).max()) / scale
    assert err < _tol(dtype), err


def _ring_from_linear(k_lin, wrap, window, ring_len):
    """Pack the last ``window`` keys before each row's cursor into the ring
    slot layout (slot j <- newest pos with pos % window == j < wrap)."""
    b = k_lin.shape[0]
    ring = np.zeros((b, k_lin.shape[1], ring_len, k_lin.shape[3]),
                    k_lin.dtype)
    for bi in range(b):
        for p in range(max(0, wrap[bi] - window), wrap[bi]):
            slot = p % window
            if slot < ring_len:
                ring[bi, :, slot] = k_lin[bi, :, p]
    return jnp.asarray(ring)


@pytest.mark.parametrize("wrap,window,ring_len,sq", [
    ([0, 5, 19], 8, 8, 4),        # cursors before/at/after the wrap
    pytest.param([13, 64], 16, 16, 8, marks=pytest.mark.slow),
    # sliced ring (bucket < window): legal only while wrap + sq <= ring_len
    pytest.param([3, 8], 16, 12, 4, marks=pytest.mark.slow),
    pytest.param([21, 40], 32, 32, 16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32,
                                   pytest.param(jnp.bfloat16,
                                                marks=pytest.mark.slow)])
def test_flash_kernel_ring(wrap, window, ring_len, sq, dtype):
    """Ring-layout semantics: attention over [ring | chunk] with kv_wrap
    must equal ordinary windowed attention over the LINEAR key sequence at
    the same offsets — for ref and Pallas (interpret) alike, including a
    ring sliced below the window (not-yet-wrapped bucket slice)."""
    b = len(wrap)
    T = max(wrap) + sq
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, 4, sq, 32), dtype)
    k_lin = jax.random.normal(ks[1], (b, 2, T, 32), dtype)
    v_lin = jax.random.normal(ks[2], (b, 2, T, 32), dtype)
    off = jnp.asarray(wrap, jnp.int32)
    # linear-layout oracle: windowed causal attention at per-row offsets
    o_lin = attention_ref(q, k_lin, v_lin, causal=True, window=window,
                          q_offset=off)
    # ring layout: [ring slots | the sq-token chunk]
    kl, vl = np.asarray(k_lin), np.asarray(v_lin)
    k_ring = [_ring_from_linear(kl, wrap, window, ring_len)]
    v_ring = [_ring_from_linear(vl, wrap, window, ring_len)]
    k_chunk = jnp.stack([k_lin[bi, :, wrap[bi]:wrap[bi] + sq]
                         for bi in range(b)])
    v_chunk = jnp.stack([v_lin[bi, :, wrap[bi]:wrap[bi] + sq]
                         for bi in range(b)])
    k_r = jnp.concatenate([k_ring[0], k_chunk], axis=2)
    v_r = jnp.concatenate([v_ring[0], v_chunk], axis=2)
    o_ref = attention_ref(q, k_r, v_r, causal=True, window=window,
                          q_offset=off, kv_wrap=off, ring_len=ring_len)
    o_k = flash_attention_pallas(q, k_r, v_r, causal=True, window=window,
                                 q_offset=off, kv_wrap=off,
                                 ring_len=ring_len, block_q=8, block_k=8,
                                 interpret=True)
    scale = float(jnp.abs(o_lin.astype(jnp.float32)).max()) + 1e-6
    for o in (o_ref, o_k):
        err = float(jnp.abs(o_lin.astype(jnp.float32)
                            - o.astype(jnp.float32)).max()) / scale
        assert err < _tol(dtype), err


def test_ring_kv_positions_formula():
    """Slot -> absolute-position recovery: newest pos with pos % window ==
    slot strictly before the cursor; negative for never-written slots."""
    wrap = jnp.asarray([0, 3, 8, 13], jnp.int32)
    kp = np.asarray(ring_kv_positions(wrap, window=8, ring_len=8, skv=12))
    for bi, w in enumerate([0, 3, 8, 13]):
        for j in range(8):
            expect = max((p for p in range(w) if p % 8 == j), default=-99)
            if expect < 0:
                assert kp[bi, j] < 0, (bi, j, kp[bi, j])
            else:
                assert kp[bi, j] == expect, (bi, j)
        for j in range(8, 12):                     # chunk tail
            assert kp[bi, j] == w + (j - 8)


# ------------------------------------------------------------ decode attn
@pytest.mark.parametrize("b,h,kvh,s,d", [
    (2, 8, 4, 200, 32),
    pytest.param(1, 4, 1, 64, 64, marks=pytest.mark.slow),
    pytest.param(3, 12, 4, 300, 16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("split_k", [
    1, 2, pytest.param(4, marks=pytest.mark.slow)])
def test_decode_kernel(b, h, kvh, s, d, split_k):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    vl = jnp.asarray(np.random.default_rng(0).integers(1, s, b), jnp.int32)
    o_ref = decode_attention_ref(q, k, v, valid_len=vl)
    o_k = decode_attention_pallas(q, k, v, valid_len=vl, block_s=64,
                                  split_k=split_k, interpret=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_decode_kernel_split_boundaries():
    """valid_len landing exactly on block / split edges: the early-exit
    predicate and the split-K combine must not read one row too many or
    drop the newest row (empty splits must vanish from the softmax)."""
    b, h, kvh, s, d = 2, 4, 2, 256, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    for edge in (1, 32, 33, 255, 256):
        vl = jnp.asarray([edge, s - edge + 1], jnp.int32)
        o_ref = decode_attention_ref(q, k, v, valid_len=vl)
        for sk in (2, 8):
            o_k = decode_attention_pallas(q, k, v, valid_len=vl, block_s=32,
                                          split_k=sk, interpret=True)
            np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                                       rtol=2e-4, atol=2e-4,
                                       err_msg=f"edge={edge} split_k={sk}")
