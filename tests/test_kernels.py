"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attn_decode.kernel import decode_attention_pallas
from repro.kernels.attn_decode.ref import decode_attention_ref
from repro.kernels.conv1d.kernel import causal_conv1d_pallas
from repro.kernels.conv1d.ref import causal_conv1d_ref
from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_ref
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_sequential

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# --------------------------------------------------------------------- SSD
# the first two shapes are the tier-1 parity smoke; the larger sweep points
# run under REPRO_RUN_SLOW=1 (scripts/verify.sh)
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 32, 2, 8, 1, 8, 8),
    (2, 64, 4, 16, 2, 16, 16),
    pytest.param(1, 128, 8, 64, 1, 32, 32, marks=pytest.mark.slow),
    pytest.param(2, 96, 4, 32, 4, 64, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel(b, s, h, p, g, n, chunk, dtype):
    ks = jax.random.split(KEY, 7)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n), dtype)
    Cm = jax.random.normal(ks[4], (b, s, g, n), dtype)
    D = jax.random.normal(ks[5], (h,))
    h0 = jax.random.normal(ks[6], (b, h, p, n), jnp.float32)
    y_ref, h_ref = ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk,
                                   initial_state=h0)
    y_k, h_k = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                          initial_state=h0, interpret=True)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    assert float(jnp.abs(y_ref.astype(jnp.float32)
                         - y_k.astype(jnp.float32)).max()) / scale < _tol(dtype)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               rtol=2e-2, atol=2e-2)


def test_ssd_kernel_matches_sequential_oracle():
    b, s, h, p, g, n = 1, 64, 2, 16, 1, 16
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, g, n))
    Cm = jax.random.normal(ks[4], (b, s, g, n))
    D = jax.random.normal(ks[5], (h,))
    y_seq, h_seq = ssd_sequential(x, dt, A, Bm, Cm, D)
    y_k, h_k = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_seq),
                               rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ conv1d
@pytest.mark.parametrize("b,s,c,k", [
    (1, 64, 32, 4),
    pytest.param(2, 128, 64, 4, marks=pytest.mark.slow),
    pytest.param(1, 256, 128, 2, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_kernel(b, s, c, k, dtype):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, s, c), dtype)
    w = jax.random.normal(ks[1], (c, k))
    bias = jax.random.normal(ks[2], (c,))
    st = jax.random.normal(ks[3], (b, k - 1, c), dtype)
    y_ref, s_ref = causal_conv1d_ref(x, w, bias, st)
    y_k, s_k = causal_conv1d_pallas(x, w, bias, initial_state=st,
                                    block_seq=min(64, s), block_ch=min(32, c),
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(s_k, np.float32),
                               np.asarray(s_ref, np.float32), rtol=1e-6)


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel(causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 8, 80, 32), dtype)
    k = jax.random.normal(ks[1], (2, 2, 80, 32), dtype)
    v = jax.random.normal(ks[2], (2, 2, 80, 32), dtype)
    o_ref = attention_ref(q, k, v, causal=causal, window=window)
    o_k = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32, interpret=True)
    scale = float(jnp.abs(o_ref.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(o_ref.astype(jnp.float32)
                        - o_k.astype(jnp.float32)).max()) / scale
    assert err < _tol(dtype), err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_q_offset(dtype):
    """Chunked-prefill shape: a short query chunk at per-row offsets
    against a long KV prefix (offset causal mask, SMEM offsets)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (3, 4, 16, 32), dtype)
    k = jax.random.normal(ks[1], (3, 2, 80, 32), dtype)
    v = jax.random.normal(ks[2], (3, 2, 80, 32), dtype)
    off = jnp.asarray([0, 13, 64], jnp.int32)
    o_ref = attention_ref(q, k, v, causal=True, q_offset=off)
    o_k = flash_attention_pallas(q, k, v, causal=True, q_offset=off,
                                 block_q=8, block_k=32, interpret=True)
    scale = float(jnp.abs(o_ref.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(o_ref.astype(jnp.float32)
                        - o_k.astype(jnp.float32)).max()) / scale
    assert err < _tol(dtype), err


# ------------------------------------------------------------ decode attn
@pytest.mark.parametrize("b,h,kvh,s,d", [
    (2, 8, 4, 200, 32),
    pytest.param(1, 4, 1, 64, 64, marks=pytest.mark.slow),
    pytest.param(3, 12, 4, 300, 16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("split_k", [
    1, 2, pytest.param(4, marks=pytest.mark.slow)])
def test_decode_kernel(b, h, kvh, s, d, split_k):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    vl = jnp.asarray(np.random.default_rng(0).integers(1, s, b), jnp.int32)
    o_ref = decode_attention_ref(q, k, v, valid_len=vl)
    o_k = decode_attention_pallas(q, k, v, valid_len=vl, block_s=64,
                                  split_k=split_k, interpret=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_kernel_split_boundaries():
    """valid_len landing exactly on block / split edges: the early-exit
    predicate and the split-K combine must not read one row too many or
    drop the newest row (empty splits must vanish from the softmax)."""
    b, h, kvh, s, d = 2, 4, 2, 256, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kvh, s, d))
    v = jax.random.normal(ks[2], (b, kvh, s, d))
    for edge in (1, 32, 33, 255, 256):
        vl = jnp.asarray([edge, s - edge + 1], jnp.int32)
        o_ref = decode_attention_ref(q, k, v, valid_len=vl)
        for sk in (2, 8):
            o_k = decode_attention_pallas(q, k, v, valid_len=vl, block_s=32,
                                          split_k=sk, interpret=True)
            np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                                       rtol=2e-4, atol=2e-4,
                                       err_msg=f"edge={edge} split_k={sk}")
