"""Unit tests for collective wire-cost models and the byte tokenizer."""
import numpy as np

from repro.data.tokenizer import BOS, PAD, batch_encode, decode, encode
from repro.distributed.collectives import (WireCost, grad_reduce_dtype_saving,
                                           overlap_headroom)


def test_wire_costs_ring_factors():
    wc = WireCost(n=16)
    b = 1024.0
    assert abs(wc.all_reduce(b) - 2 * b * 15 / 16) < 1e-9
    assert abs(wc.all_gather(b) - b * 15 / 16) < 1e-9
    assert abs(wc.reduce_scatter(b) - b * 15 / 16) < 1e-9
    # AR == RS + AG (the sequence-parallel identity)
    assert abs(wc.all_reduce(b)
               - (wc.reduce_scatter(b) + wc.all_gather(b))) < 1e-9


def test_overlap_headroom():
    assert overlap_headroom(10.0, 5.0) == 1.0
    assert overlap_headroom(5.0, 10.0) == 0.5
    assert overlap_headroom(1.0, 0.0) == 1.0


def test_grad_compression_halves_wire():
    full, comp = grad_reduce_dtype_saving(1e9, 16)
    assert abs(full / comp - 2.0) < 1e-9


def test_tokenizer_roundtrip():
    s = "hello, 世界!"
    ids = encode(s, bos=True, eos=True)
    assert ids[0] == BOS
    assert decode(ids) == s


def test_batch_encode_pads():
    out = batch_encode(["ab", "cdef"], pad_to=8)
    assert out.shape == (2, 8)
    assert (out[0, -1] == PAD) and (out[1, 5] != PAD or out[1, 5] == PAD)
    assert decode(out[0]) == "ab"
