"""Workload → model-input specs (ShapeDtypeStructs; never allocates).

Modality stubs per the assignment: [audio] archs take precomputed frame
embeddings, [vlm] archs take precomputed patch embeddings alongside tokens.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, WorkloadConfig

VLM_PATCHES = 576          # llava-next: 24x24 patch grid per image
VLM_FEAT_DIM = 1024        # CLIP-L vision features
AUDIO_FEAT_DIM = 512       # wav2vec2/hubert conv-extractor features


def input_specs(cfg: ModelConfig, wl: WorkloadConfig) -> Dict[str, Any]:
    """Specs for the *model inputs* of the step lowered for this workload.

    train:   full-sequence inputs + labels
    prefill: full-sequence inputs
    decode:  one-token inputs (the KV/state cache is built separately via
             ``cache_specs``)
    """
    b, s = wl.global_batch, wl.seq_len
    tok = jnp.int32
    if wl.kind == "decode":
        if cfg.frontend == "audio":
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        return {"tokens": jax.ShapeDtypeStruct((b, 1), tok)}
    if cfg.frontend == "audio":
        specs = {"features": jax.ShapeDtypeStruct((b, s, AUDIO_FEAT_DIM),
                                                  jnp.bfloat16)}
        if wl.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), tok)
        return specs
    if cfg.frontend == "vision":
        s_text = s - VLM_PATCHES
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s_text), tok),
            "features": jax.ShapeDtypeStruct((b, VLM_PATCHES, VLM_FEAT_DIM),
                                             jnp.bfloat16),
        }
        if wl.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), tok)
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
    if wl.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), tok)
    return specs


def realize(specs, seed: int = 0):
    """Materialize concrete arrays for smoke tests / examples."""
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, sd in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(sd.dtype, jnp.integer):
            out[name] = jax.random.randint(k, sd.shape, 0, 100, sd.dtype)
        else:
            out[name] = jax.random.normal(k, sd.shape, sd.dtype)
    return out


def applicable(cfg: ModelConfig, wl: WorkloadConfig) -> Tuple[bool, str]:
    """Assignment rules: encoder-only archs skip decode; long_500k requires
    sub-quadratic attention."""
    if cfg.family in ("encoder", "audio") and wl.kind == "decode":
        return False, "encoder-only: no decode step"
    if wl.name == "long_500k":
        kinds = set(cfg.layer_kinds)
        full_attn = kinds & {"dense", "moe", "dense_moe", "encoder"}
        sub_quadratic = kinds & {"mamba2", "mamba1", "mamba2+shared", "local"}
        if not sub_quadratic:
            return False, "pure full-attention arch: long_500k skipped"
        if full_attn and "local" not in kinds:
            return False, ("full-attention layers present: long_500k skipped "
                           "(quadratic prefill history)")
        # local:global archs (gemma3) run: decode is linear per step and the
        # global-layer KV cache is sequence-sharded.
    return True, ""
