"""Operator taxonomy re-exports (classification lives with the HLO parser).

Paper Sec. II-C: GEMM | non-GEMM{memory, arith, norm} | SSM-specific, plus
collectives (a distributed-runtime class the paper's single-GPU study does
not need, reported separately here).
"""
from repro.core.hlo_analysis import (  # noqa: F401
    ARITH_OPS, COLLECTIVE_OPS, MEMORY_OPS, NORM_SCOPES, SSM_SCOPES,
)

CLASSES = ("gemm", "ssm", "memory", "arith", "norm", "collective", "other")

# Display order mirrors the paper's stacked bars (SSM at the bottom,
# then GEMM, then non-GEMM sorted by contribution).
DISPLAY_ORDER = ("ssm", "gemm", "norm", "arith", "memory", "collective",
                 "other")
