"""HLO-text cost analysis: per-kernel FLOPs / HBM bytes / collective bytes.

Why not ``compiled.cost_analysis()``?  Verified in this container: XLA's
aggregate cost analysis counts a ``while`` body (lax.scan over layers)
**once**, independent of trip count — a 94-layer scanned model would be
undercounted by ~94x.  This module parses the post-SPMD optimized HLO
(``compiled.as_text()``), multiplies loop bodies by the
``known_trip_count`` backend annotation, and models each *fusion as one
kernel*: HBM traffic = the fusion's operands + results (interior values
stay in registers/VMEM), FLOPs = sum over interior ops.

It also classifies every executed kernel into the paper's operator
taxonomy (GEMM / non-GEMM{memory, arith, norm} / SSM-specific /
collective) using ``jax.named_scope`` metadata preserved in
``metadata={op_name=...}`` — the same breakdown the paper extracts from
torch.profiler, derived analytically.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

def xla_cost_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalised across jax versions: older
    releases return a one-element list of per-device dicts, newer ones the
    dict itself (and either may be None)."""
    xca = compiled.cost_analysis()
    if isinstance(xca, (list, tuple)):
        xca = xca[0] if xca else None
    return dict(xca) if xca else {}


DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

MEMORY_OPS = {
    "reshape", "transpose", "copy", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "gather", "scatter", "pad",
    "broadcast", "reverse", "bitcast-convert", "copy-start", "copy-done",
}
ARITH_OPS = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt", "cbrt",
    "tanh", "logistic", "sine", "cosine", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "maximum", "minimum",
    "compare", "select", "clamp", "and", "or", "xor", "not", "convert",
    "reduce", "reduce-window", "map", "iota", "rng", "rng-bit-generator",
    "erf", "atan2", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "reduce-precision", "stochastic-convert",
}
COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}
ZERO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "token", "partition-id", "replica-id", "domain",
    "opt-barrier", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "async-done", "custom-call",
}

# named_scope → paper operator class (priority order).  "decode_fused" is
# the serving decode-step recurrence (fused conv shift + SSM state update,
# src/repro/kernels/decode_fused/) — it IS the custom SSM kernel on the
# decode path, so its ops belong to the ssm family, not arith/memory.
SSM_SCOPES = ("ssd_core", "ssm_core", "conv1d", "ssm_gate", "decode_fused")
NORM_SCOPES = ("norm",)


@dataclass
class Op:
    name: str
    opcode: str
    shapes: List[Tuple[str, Tuple[int, ...]]]   # result (dtype, dims) list
    operands: List[str]
    attrs: str
    op_name: str = ""                           # metadata scope path

    def result_bytes(self) -> int:
        return sum(int(np.prod(d, dtype=np.int64)) * DTYPE_BYTES.get(t, 4)
                   for t, d in self.shapes)

    def result_elems(self) -> int:
        return sum(int(np.prod(d, dtype=np.int64)) for t, d in self.shapes)


@dataclass
class KernelCost:
    name: str
    opcode: str
    clazz: str
    scope: str
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0     # per-device wire bytes
    count: float = 1.0          # loop-trip multiplier applied


@dataclass
class CostSummary:
    kernels: List[KernelCost] = field(default_factory=list)

    @property
    def flops(self) -> float:
        return sum(k.flops * k.count for k in self.kernels)

    @property
    def bytes(self) -> float:
        return sum(k.bytes * k.count for k in self.kernels)

    @property
    def coll_bytes(self) -> float:
        return sum(k.coll_bytes * k.count for k in self.kernels)

    def by_class(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0, "n": 0.0})
        for k in self.kernels:
            c = out[k.clazz]
            c["flops"] += k.flops * k.count
            c["bytes"] += k.bytes * k.count
            c["coll_bytes"] += k.coll_bytes * k.count
            c["n"] += k.count
        return dict(out)

    def by_scope(self, depth: int = 1) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"flops": 0.0, "bytes": 0.0})
        for k in self.kernels:
            scope = k.scope or "(unscoped)"
            c = out[scope]
            c["flops"] += k.flops * k.count
            c["bytes"] += k.bytes * k.count
        return dict(out)


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)(?:\s*\(.*\))?\s+->\s+.*\{")
_METADATA_RE = re.compile(r'metadata=\{[^}]*op_name="([^"]*)"')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group(1)
        if dtype not in DTYPE_BYTES:
            continue
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append((dtype, dims))
    return out


def parse_hlo(text: str) -> Dict[str, List[Op]]:
    """Parse HLO text into {computation_name: [ops]}."""
    comps: Dict[str, List[Op]] = {}
    entry_name = None
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                current = m.group(1)
                comps[current] = []
                if line.startswith("ENTRY"):
                    entry_name = current
            continue
        if line.startswith("}") or line.strip() == "}":
            current = None
            continue
        m = _NAME_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # result type: tuple types "(f32[..], /*index=1*/ f32[..])" contain
        # parens and '=' (index comments) — scan to the matching ')'.
        if rest.startswith("("):
            depth = 0
            i = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            type_str, rest = rest[:i + 1], rest[i + 1:]
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            type_str, rest = rest[:sp], rest[sp:]
        m2 = _OPCODE_RE.match(rest)
        if not m2:
            continue
        opcode = m2.group(1)
        rest = rest[m2.end():]
        # operands: up to the closing paren at depth 0
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str, attrs = rest[:i], rest[i:]
        md = _METADATA_RE.search(line)
        comps[current].append(Op(
            name=name, opcode=opcode, shapes=_parse_shapes(type_str),
            operands=_OPERANDS_RE.findall(operand_str), attrs=attrs,
            op_name=md.group(1) if md else ""))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _group_size(attrs: str, default: int = 1) -> int:
    m = _IOTA_GROUPS_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(attrs)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


def _scope_of(op_name: str) -> str:
    """Last interesting named_scope component of the metadata path."""
    parts = [p for p in op_name.split("/") if p]
    known = SSM_SCOPES + NORM_SCOPES + (
        "attn_core", "attn_decode", "qkv_proj", "o_proj", "rope", "mlp",
        "moe_route",
        "moe_dispatch", "moe_expert", "moe_combine", "moe_shared_expert",
        "embed", "lm_head", "ssm_in_proj", "ssm_out_proj", "optimizer",
        "loss", "grad_compress")
    for p in reversed(parts):
        for k in known:
            # grad ops carry wrapped paths like "transpose(jvp(mlp))"
            if k in p:
                return k
    return parts[-1] if parts else ""


def _dot_flops(op: Op, shape_env: Dict[str, List[Tuple[str, Tuple[int, ...]]]]
               ) -> float:
    out_elems = op.result_elems()
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1
    lhs_shapes = shape_env.get(op.operands[0]) if op.operands else None
    if m and lhs_shapes:
        dims = lhs_shapes[0][1]
        for idx in (int(x) for x in m.group(1).split(",") if x):
            if idx < len(dims):
                contract *= dims[idx]
    return 2.0 * out_elems * max(contract, 1)


def _conv_flops(op: Op, shape_env) -> float:
    out_elems = op.result_elems()
    m = re.search(r"window=\{size=([\dx]+)", op.attrs)
    ksize = 1
    if m:
        for x in m.group(1).split("x"):
            ksize *= int(x)
    rhs = shape_env.get(op.operands[1]) if len(op.operands) > 1 else None
    in_ch = rhs[0][1][-2] if rhs and len(rhs[0][1]) >= 2 else 1
    return 2.0 * out_elems * ksize * max(in_ch, 1)


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        # shape env: op name -> result shapes (across all comps; names unique)
        self.shape_env: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = {}
        for ops in self.comps.values():
            for op in ops:
                self.shape_env[op.name] = op.shapes
        self._flops_cache: Dict[str, float] = {}

    # -- interior FLOPs of a computation (fusion bodies, called comps) ------
    def _comp_flops(self, comp: str) -> float:
        if comp in self._flops_cache:
            return self._flops_cache[comp]
        self._flops_cache[comp] = 0.0   # cycle guard
        total = 0.0
        for op in self.comps.get(comp, []):
            total += self._op_interior_flops(op)
        self._flops_cache[comp] = total
        return total

    def _op_interior_flops(self, op: Op) -> float:
        oc = op.opcode
        if oc == "dot":
            return _dot_flops(op, self.shape_env)
        if oc == "convolution":
            return _conv_flops(op, self.shape_env)
        if oc == "fusion" or oc == "call":
            m = _CALLS_RE.search(op.attrs) or re.search(
                r"to_apply=%?([\w\.\-]+)", op.attrs)
            return self._comp_flops(m.group(1)) if m else 0.0
        if oc == "while":
            mb, mc = _BODY_RE.search(op.attrs), _COND_RE.search(op.attrs)
            mt = _TRIP_RE.search(op.attrs)
            trips = int(mt.group(1)) if mt else 1
            inner = 0.0
            if mb:
                inner += self._comp_flops(mb.group(1))
            if mc:
                inner += self._comp_flops(mc.group(1))
            return trips * inner
        if oc == "conditional":
            m = _BRANCHES_RE.search(op.attrs)
            if m:
                names = [x.strip().lstrip("%") for x in m.group(1).split(",")]
                return max((self._comp_flops(n) for n in names), default=0.0)
            return 0.0
        if oc in ARITH_OPS:
            return float(op.result_elems())
        return 0.0

    # -- operand bytes --------------------------------------------------------
    def _operand_bytes(self, op: Op) -> float:
        total = 0.0
        for name in op.operands:
            shapes = self.shape_env.get(name)
            if shapes:
                total += sum(int(np.prod(d, dtype=np.int64))
                             * DTYPE_BYTES.get(t, 4) for t, d in shapes)
        return total

    def _name_bytes(self, name: str) -> float:
        shapes = self.shape_env.get(name)
        if not shapes:
            return 0.0
        return sum(int(np.prod(d, dtype=np.int64)) * DTYPE_BYTES.get(t, 4)
                   for t, d in shapes)

    def _kernel_bytes(self, op: Op) -> float:
        """HBM traffic of one kernel.

        Two in-place/sparse-access patterns XLA handles that a naive
        operands+results sum over-charges by orders of magnitude:
          * dynamic-update-slice roots alias the big buffer — only the
            update slice moves;
          * fusion operands consumed ONLY by (dynamic-)slice/gather interior
            ops — only the slice results move.
        """
        if op.opcode == "dynamic-update-slice":
            upd = (self._name_bytes(op.operands[1])
                   if len(op.operands) > 1 else 0.0)
            return max(2.0 * upd, 1.0)
        if op.opcode != "fusion":
            return self._operand_bytes(op) + op.result_bytes()
        m = _CALLS_RE.search(op.attrs)
        interior = self.comps.get(m.group(1), []) if m else []
        if not interior:
            return self._operand_bytes(op) + op.result_bytes()
        params: Dict[str, int] = {}
        for io in interior:
            if io.opcode == "parameter":
                mi = re.match(r"param_(\d+)", io.name)
                if mi:
                    params[io.name] = int(mi.group(1))
        consumers: Dict[str, List[Op]] = {}
        for io in interior:
            for o in io.operands:
                consumers.setdefault(o, []).append(io)
        sliced: Dict[int, float] = {}
        for pname, idx in params.items():
            cons = consumers.get(pname, [])
            if cons and all(c.opcode in ("dynamic-slice", "slice", "gather")
                            for c in cons):
                sliced[idx] = sum(c.result_bytes() for c in cons)
        total = 0.0
        for i, oname in enumerate(op.operands):
            total += sliced[i] if i in sliced else self._name_bytes(oname)
        root = interior[-1]
        if root.opcode == "dynamic-update-slice":
            # in-place update: write = update slice only, and the aliased
            # full-buffer operand is not streamed — drop its read charge.
            upd = (self._name_bytes(root.operands[1])
                   if len(root.operands) > 1 else 0.0)
            total += upd
            for i, oname in enumerate(op.operands):
                if i in sliced:
                    continue
                if abs(self._name_bytes(oname) - op.result_bytes()) < 1:
                    total -= self._name_bytes(oname)
                    break
        else:
            total += op.result_bytes()
        return max(total, 1.0)

    # -- classification -------------------------------------------------------
    def _classify(self, op: Op) -> str:
        scope_path = op.op_name
        if any(s in scope_path for s in SSM_SCOPES):
            return "ssm"
        if op.opcode in COLLECTIVE_OPS:
            return "collective"
        if op.opcode in ("dot", "convolution"):
            return "gemm"
        if op.opcode in ("fusion", "call"):
            m = _CALLS_RE.search(op.attrs) or re.search(
                r"to_apply=%?([\w\.\-]+)", op.attrs)
            if m:
                interior = self.comps.get(m.group(1), [])
                if any(o.opcode in ("dot", "convolution") for o in interior):
                    return "gemm"
        if any(s in scope_path for s in NORM_SCOPES):
            return "norm"
        if op.opcode in MEMORY_OPS:
            return "memory"
        if op.opcode in ARITH_OPS:
            return "arith"
        if op.opcode == "fusion":
            m = _CALLS_RE.search(op.attrs)
            interior = self.comps.get(m.group(1), []) if m else []
            if any(o.opcode in ARITH_OPS for o in interior):
                return "arith"
            return "memory"
        return "other"

    # -- kernel walk ----------------------------------------------------------
    def _walk(self, comp: str, mult: float, out: List[KernelCost]) -> None:
        for op in self.comps.get(comp, []):
            oc = op.opcode
            if oc in ZERO_COST_OPS and oc not in COLLECTIVE_OPS:
                # custom-call: count bytes (conservative), no flops
                if oc == "custom-call":
                    out.append(KernelCost(
                        name=op.name, opcode=oc, clazz="other",
                        scope=_scope_of(op.op_name),
                        bytes=self._operand_bytes(op) + op.result_bytes(),
                        count=mult))
                continue
            if oc == "while":
                mb, mc = _BODY_RE.search(op.attrs), _COND_RE.search(op.attrs)
                mt = _TRIP_RE.search(op.attrs)
                trips = int(mt.group(1)) if mt else 1
                if mb:
                    self._walk(mb.group(1), mult * trips, out)
                if mc:
                    self._walk(mc.group(1), mult * trips, out)
                continue
            if oc == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", op.attrs)
                if m:
                    self._walk(m.group(1), mult, out)
                continue
            if oc == "conditional":
                m = _BRANCHES_RE.search(op.attrs)
                if m:
                    names = [x.strip().lstrip("%") for x in m.group(1).split(",")]
                    costs = []
                    for n in names:
                        sub: List[KernelCost] = []
                        self._walk(n, mult, sub)
                        costs.append((sum(k.flops + k.bytes for k in sub), sub))
                    if costs:
                        out.extend(max(costs, key=lambda c: c[0])[1])
                continue
            clazz = self._classify(op)
            scope_name = op.op_name
            if not scope_name and op.opcode == "fusion":
                # XLA wrapper fusions (wrapped_*) drop metadata: inherit the
                # scope from interior ops
                m = _CALLS_RE.search(op.attrs)
                for io in (self.comps.get(m.group(1), []) if m else []):
                    if io.op_name:
                        scope_name = io.op_name
                        break
                if clazz in ("arith", "memory", "other"):
                    redo = self._classify(Op(op.name, op.opcode, op.shapes,
                                             op.operands, op.attrs,
                                             scope_name))
                    clazz = redo
            flops = self._op_interior_flops(op)
            byts = self._kernel_bytes(op)
            coll = 0.0
            if clazz == "collective":
                n = _group_size(op.attrs, default=2)
                opb = self._operand_bytes(op)
                outb = op.result_bytes()
                base = oc.replace("-start", "")
                if base == "all-gather":
                    coll = outb * (n - 1) / max(n, 1)
                elif base == "all-reduce":
                    coll = 2.0 * opb * (n - 1) / max(n, 1)
                elif base == "reduce-scatter":
                    coll = opb * (n - 1) / max(n, 1)
                elif base in ("all-to-all", "ragged-all-to-all"):
                    coll = opb * (n - 1) / max(n, 1)
                else:  # collective-permute / broadcast
                    coll = opb
            out.append(KernelCost(name=op.name, opcode=oc, clazz=clazz,
                                  scope=_scope_of(scope_name), flops=flops,
                                  bytes=byts, coll_bytes=coll, count=mult))

    def summarize(self) -> CostSummary:
        out: List[KernelCost] = []
        self._walk("__entry__", 1.0, out)
        return CostSummary(kernels=out)

    # -- fused-region analysis -------------------------------------------------
    # Models the deployed Pallas-kernel path: all ops sharing a fusable
    # named_scope within one computation become ONE kernel whose HBM bytes
    # are the region's external inputs + outputs (interior stays in VMEM),
    # exactly like the paper's fused `mamba_split_conv1d_scan_combined`.
    FUSABLE = ("attn_core", "ssd_core", "ssm_core", "conv1d", "ssm_gate",
               "norm", "rope")
    # the deployed mamba kernel fuses conv1d + scan + gate into ONE kernel
    # (mamba_split_conv1d_scan_combined) — model the same fusion boundary.
    SUPER_REGION = {"conv1d": "ssm_combined", "ssd_core": "ssm_combined",
                    "ssm_core": "ssm_combined", "ssm_gate": "ssm_combined"}

    def _region_scopes(self, scope: str) -> Tuple[str, ...]:
        region = self.SUPER_REGION.get(scope)
        if region is None:
            return (scope,)
        return tuple(s for s, r in self.SUPER_REGION.items() if r == region)

    def _region_bytes(self, comp: str, scope: str) -> Tuple[float, float]:
        ops = self.comps.get(comp, [])
        scopes = set(self._region_scopes(scope))
        member = {op.name for op in ops if _scope_of(op.op_name) in scopes}
        if not member:
            return 0.0, 0.0
        raw = 0.0
        io = 0.0
        consumed_outside = set()
        for op in ops:
            if op.name in member:
                continue
            for o in op.operands:
                if o in member:
                    consumed_outside.add(o)
        for op in ops:
            if op.name not in member:
                continue
            raw += self._operand_bytes(op) + op.result_bytes()
            for o in op.operands:
                if o not in member:
                    shapes = self.shape_env.get(o)
                    if shapes:
                        io += sum(int(np.prod(d, dtype=np.int64))
                                  * DTYPE_BYTES.get(t, 4) for t, d in shapes)
            if op.name in consumed_outside:
                io += op.result_bytes()
        # ROOT results count as outputs
        if ops and ops[-1].name in member and ops[-1].name not in consumed_outside:
            io += ops[-1].result_bytes()
        return raw, io

    def summarize_fused(self) -> CostSummary:
        """CostSummary with fusable scope-regions collapsed to single
        kernels (per computation, trip-count preserved)."""
        out: List[KernelCost] = []
        self._walk("__entry__", 1.0, out)
        # group kernels by (computation-agnostic) identity: recover the
        # computation of each op name
        op_comp: Dict[str, str] = {}
        for comp, ops in self.comps.items():
            if comp == "__entry__":
                continue
            for op in ops:
                op_comp[op.name] = comp
        region_cache: Dict[Tuple[str, str], Tuple[float, float]] = {}
        fused: Dict[Tuple[str, str], KernelCost] = {}
        rest: List[KernelCost] = []
        for k in out:
            if k.scope not in self.FUSABLE or k.clazz == "collective":
                rest.append(k)
                continue
            comp = op_comp.get(k.name, "")
            region = self.SUPER_REGION.get(k.scope, k.scope)
            key = (comp, region)
            if key not in region_cache:
                region_cache[key] = self._region_bytes(comp, k.scope)
            raw, io = region_cache[key]
            scale = io / raw if raw else 1.0
            if key not in fused:
                clazz = ("ssm" if (k.scope in SSM_SCOPES
                                   or region == "ssm_combined") else
                         "norm" if k.scope in NORM_SCOPES else "gemm")
                fused[key] = KernelCost(
                    name=f"fused_{region}", opcode="fused-region",
                    clazz=clazz, scope=region, count=k.count)
            fk = fused[key]
            fk.flops += k.flops * (k.count / fk.count)
            fk.bytes += k.bytes * scale * (k.count / fk.count)
        return CostSummary(kernels=rest + list(fused.values()))


def analyze_hlo_text(text: str) -> CostSummary:
    return HloAnalyzer(text).summarize()


def analyze_hlo_text_fused(text: str) -> CostSummary:
    return HloAnalyzer(text).summarize_fused()


def analyze_compiled(compiled) -> CostSummary:
    return analyze_hlo_text(compiled.as_text())
