"""Energy model (paper Fig. 6a analog).

The paper integrates nvidia-smi power over the run.  We model
  E = T_modeled × P_active + T_modeled × P_idle_residual
with T from the per-kernel roofline times (max of compute/memory per
kernel, summed — the no-overlap upper bound matches eager-mode execution,
which is what the paper measured with the HF pipeline).
"""
from __future__ import annotations

from typing import Dict

from repro.core.config import HardwareSpec
from repro.core.hlo_analysis import CostSummary
from repro.core.roofline import op_class_times


def modeled_time(cost: CostSummary, hw: HardwareSpec) -> float:
    return sum(op_class_times(cost, hw).values())


def modeled_energy(cost: CostSummary, hw: HardwareSpec) -> float:
    t = modeled_time(cost, hw)
    # compute-heavy kernels draw near peak power; memory-bound ones less.
    times = op_class_times(cost, hw)
    e = 0.0
    for clazz, tc in times.items():
        util = 0.9 if clazz == "gemm" else 0.55
        e += tc * (hw.idle_w + util * (hw.power_w - hw.idle_w))
    return e


def energy_report(cost: CostSummary, hw: HardwareSpec) -> Dict[str, float]:
    return {"time_s": modeled_time(cost, hw),
            "energy_j": modeled_energy(cost, hw)}
