"""Three-term roofline from the HLO cost summary.

  compute    = HLO_FLOPs(per device)      / peak_FLOP/s
  memory     = HLO_bytes(per device)      / HBM_bw
  collective = wire_bytes(per device)     / (links × link_bw)

The HLO module analyzed is the post-SPMD per-device module, so all terms
are already per chip.  ``useful_ratio`` = MODEL_FLOPS/chips / HLO_FLOPs
(catches remat/redundancy/padding waste).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.config import HardwareSpec, ModelConfig, WorkloadConfig
from repro.core.hlo_analysis import CostSummary

# TPU v5e: 4 ICI links per chip in a 2D torus.
DEFAULT_LINKS = 4


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    class_breakdown: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Lower-bound step time: perfectly overlapped terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        """Upper-bound step time: no overlap at all."""
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def useful_ratio(self) -> float:
        per_dev = self.model_flops / max(self.chips, 1)
        return per_dev / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        if self.t_bound <= 0:
            return 0.0
        per_dev = self.model_flops / max(self.chips, 1)
        return per_dev / self.t_bound  # FLOP/s achieved per chip


def model_flops(cfg: ModelConfig, wl: WorkloadConfig) -> float:
    """6·N·D for training, 2·N·D for inference (N_active for MoE)."""
    n = cfg.active_param_count()
    if wl.kind == "train":
        tokens = wl.tokens
        return 6.0 * n * tokens
    if wl.kind == "prefill":
        return 2.0 * n * wl.tokens
    # decode: one token per sequence in the batch
    return 2.0 * n * wl.global_batch


def compute_roofline(cost: CostSummary, hw: HardwareSpec, *, chips: int,
                     arch: str, shape: str, mesh: str,
                     mflops: float, links: int = DEFAULT_LINKS
                     ) -> RooflineReport:
    t_c = cost.flops / hw.peak_flops
    t_m = cost.bytes / hw.hbm_bw
    t_l = (cost.coll_bytes / (links * hw.link_bw)) if hw.link_bw else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        t_compute=t_c, t_memory=t_m, t_collective=t_l,
        hlo_flops=cost.flops, hlo_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes, model_flops=mflops,
        class_breakdown=cost.by_class())


def op_class_times(cost: CostSummary, hw: HardwareSpec) -> Dict[str, float]:
    """Per-operator-class modeled latency (paper Figs. 7-9 analog):
    each kernel takes max(compute, memory) on this device; collectives take
    wire time."""
    times: Dict[str, float] = {}
    for k in cost.kernels:
        t = max(k.flops / hw.peak_flops,
                k.bytes / hw.hbm_bw)
        if k.clazz == "collective" and hw.link_bw:
            t = max(t, k.coll_bytes / (DEFAULT_LINKS * hw.link_bw))
        times[k.clazz] = times.get(k.clazz, 0.0) + t * k.count
    return times


def op_scope_times(cost: CostSummary, hw: HardwareSpec) -> Dict[str, float]:
    times: Dict[str, float] = {}
    for k in cost.kernels:
        t = max(k.flops / hw.peak_flops, k.bytes / hw.hbm_bw) * k.count
        times[k.scope or "(unscoped)"] = times.get(k.scope or "(unscoped)", 0.0) + t
    return times
