"""Analytic inference-memory model + OOM frontier (paper Fig. 5, eqs. 2-3).

  weights      = N_params × p
  KV cache     = B × S × Σ_attn-layers (2 × n_kv × head_dim) × p   (eq. 2, GQA-aware)
  SSM state    = B × Σ_ssm-layers (H×P×N × 4 + conv window)         (constant in S)
  activations  ≈ B × S × D × C × p                                  (eq. 3)

The paper measures peak reserved memory under the HF pipeline; we model the
same quantities plus a configurable framework-overhead fraction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.config import ModelConfig

# Paper Sec. II-B: "C: number of layers to keep their activations on memory".
DEFAULT_ACT_LAYERS = 2
# Allocator/framework overhead fraction observed with eager HF pipelines.
DEFAULT_OVERHEAD = 0.08


def weight_bytes(cfg: ModelConfig, p: int = 2) -> int:
    return cfg.param_count() * p


def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int, p: int = 2) -> int:
    total = 0
    a = cfg.attn
    for kind in cfg.layer_kinds:
        if kind in ("dense", "moe", "dense_moe", "encoder"):
            total += 2 * batch * seq * a.n_kv_heads * a.head_dim * p
        elif kind == "local":
            # rolling caches always span the full window (init_attn_cache):
            # the rolling-slot invariant needs every window row even when
            # the nominal seq is shorter
            s_eff = a.sliding_window or seq
            total += 2 * batch * s_eff * a.n_kv_heads * a.head_dim * p
        elif kind == "mamba2+shared" and cfg.shared_attn is not None:
            sa = cfg.shared_attn
            total += 2 * batch * seq * sa.n_kv_heads * sa.head_dim * p
    return total


def ssm_state_bytes(cfg: ModelConfig, batch: int, p_state: int = 4,
                    p: int = 2) -> int:
    if cfg.ssm is None:
        return 0
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    total = 0
    for kind in cfg.layer_kinds:
        if kind in ("mamba2", "mamba2+shared"):
            nh = s.n_ssm_heads(cfg.d_model)
            conv_dim = di + 2 * s.n_groups * s.d_state
            total += batch * (nh * s.headdim * s.d_state * p_state
                              + (s.conv_kernel - 1) * conv_dim * p)
        elif kind == "mamba1":
            total += batch * (di * s.d_state * p_state
                              + (s.conv_kernel - 1) * di * p)
    return total


def activation_bytes(cfg: ModelConfig, batch: int, seq: int, p: int = 2,
                     c_layers: int = DEFAULT_ACT_LAYERS,
                     logits_mode: Optional[str] = None,
                     eager_attention: bool = False) -> int:
    """eq. 3 + the two buffers that actually set the paper's OOM frontier:

    * full-sequence logits — the HF pipeline materializes [B, S, V] at
      prefill (≈304 KB/token for Qwen2.5's 152K vocab!); the official
      mamba_ssm runtime computes last-token logits only (num_last_tokens=1).
      Default: "full" for attention-bearing (HF-served) families, "last"
      for pure SSM.
    * eager attention scores — [B, H, S, S] f32 (×2 for the softmax copy)
      for models running without FlashAttention (paper: Phi-3's classical
      decoder OOMs between 4K and 8K on 24 GB exactly because of this).
    """
    act = batch * seq * cfg.d_model * c_layers * p
    if logits_mode is None:
        logits_mode = "last" if cfg.family == "ssm" else "full"
    if logits_mode == "full":
        logits = batch * seq * cfg.padded_vocab * p
    else:
        logits = batch * cfg.padded_vocab * 4
    scores = 0
    if eager_attention and cfg.attn is not None:
        scores = 2 * batch * cfg.attn.n_heads * seq * seq * 4
    return act + logits + scores


@dataclass
class MemoryBreakdown:
    weights: int
    kv_cache: int
    ssm_state: int
    activations: int
    overhead: int

    @property
    def total(self) -> int:
        return (self.weights + self.kv_cache + self.ssm_state
                + self.activations + self.overhead)

    def as_dict(self) -> Dict[str, int]:
        return {"weights": self.weights, "kv_cache": self.kv_cache,
                "ssm_state": self.ssm_state, "activations": self.activations,
                "overhead": self.overhead, "total": self.total}


def inference_memory(cfg: ModelConfig, batch: int, seq: int, p: int = 2,
                     overhead_frac: float = DEFAULT_OVERHEAD,
                     logits_mode: Optional[str] = None,
                     eager_attention: bool = False) -> MemoryBreakdown:
    w = weight_bytes(cfg, p)
    kv = kv_cache_bytes(cfg, batch, seq, p)
    ssm = ssm_state_bytes(cfg, batch, p=p)
    act = activation_bytes(cfg, batch, seq, p, logits_mode=logits_mode,
                           eager_attention=eager_attention)
    ovh = int((w + kv + ssm + act) * overhead_frac)
    return MemoryBreakdown(w, kv, ssm, act, ovh)


def max_seq_len(cfg: ModelConfig, capacity_bytes: float, batch: int = 1,
                p: int = 2, hi: int = 1 << 22,
                logits_mode: Optional[str] = None,
                eager_attention: bool = False) -> int:
    """OOM frontier: largest prefill length fitting in ``capacity_bytes``."""
    def fits(s):
        return inference_memory(
            cfg, batch, s, p, logits_mode=logits_mode,
            eager_attention=eager_attention).total <= capacity_bytes
    if not fits(1):
        return 0
    lo, h = 1, hi
    while lo < h:
        mid = (lo + h + 1) // 2
        if fits(mid):
            lo = mid
        else:
            h = mid - 1
    return lo
