"""Configuration dataclasses for models, workloads and hardware.

These are the inputs to the characterization flow (paper Fig. 4): the model
registry stores ``ModelConfig``s, the workload configuration is a
``WorkloadConfig``, and the roofline/energy models consume ``HardwareSpec``s.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # window size for "local" layers
    causal: bool = True                   # False for encoder-only (hubert)
    # "auto": dense masked attention for short seqs, chunked online-softmax
    # (flash-style) beyond ``dense_cutoff`` tokens.
    impl: str = "auto"
    dense_cutoff: int = 8192
    qk_norm: bool = False                 # qwen3-style per-head RMSNorm on q/k

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    variant: str = "mamba2"   # "mamba2" (SSD) | "mamba1" (selective scan)
    headdim: int = 64         # mamba2 head dim (P)
    expand: int = 2
    n_groups: int = 1         # B/C groups (mamba2)
    conv_kernel: int = 4
    chunk: int = 128          # SSD chunk length (MXU-aligned)
    dt_rank: Optional[int] = None  # mamba1: rank of the dt projection

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    interleave_step: int = 1     # MoE layer every k-th layer (llama4: 2)
    shared_expert: bool = False  # llama4-style always-on shared expert
    router_dtype: str = "float32"
    impl: str = "gshard"         # "gshard" einsum dispatch | "ragged" sort-based


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnConfig] = None
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None
    # The repeating unit of layer kinds.  Layer kinds:
    #   "dense"       GQA attention + MLP
    #   "local"       sliding-window GQA attention + MLP
    #   "moe"         GQA attention + MoE FF
    #   "dense_moe"   dense layer at MoE interleave positions (llama4)
    #   "mamba2"      SSD block
    #   "mamba1"      selective-scan block
    #   "mamba2+shared"  mamba2 block followed by the shared attention block (zamba2)
    #   "encoder"     bidirectional attention + MLP (hubert)
    layer_pattern: Tuple[str, ...] = ("dense",)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"
    frontend: str = "none"       # none | audio | vision
    # vision/audio stub: number of prefix embedding positions comes from the
    # workload; the frontend projects precomputed features of this dim.
    frontend_feature_dim: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    # Zamba2: one shared transformer block applied at "mamba2+shared" positions.
    shared_attn: Optional[AttnConfig] = None
    shared_attn_d_ff: int = 0
    scan_layers: bool = True     # scan-over-layers (compact HLO); False unrolls
    remat: str = "block"         # "none" | "block" (remat each scanned unit)
    # FSDP: shard the d_model dim of *params* over the data axis (ZeRO-3).
    # Used when attention heads don't divide the model axis (llama4: 40 heads)
    # so head-replicated attention weights would otherwise blow up HBM.
    fsdp: bool = False

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Full per-layer kind list of length n_layers."""
        reps = math.ceil(self.n_layers / len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.n_layers]

    def segments(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Decompose the layer list into (unit, n_repeat) scan segments."""
        kinds = self.layer_kinds
        unit = self.layer_pattern
        n_full, rem = divmod(self.n_layers, len(unit))
        segs = []
        if n_full:
            segs.append((unit, n_full))
        if rem:
            segs.append((tuple(kinds[-rem:]), 1))
        return tuple(segs)

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        total += D  # final norm
        for kind in self.layer_kinds:
            total += self._layer_params(kind)
        if self.shared_attn is not None:
            a = self.shared_attn
            total += (self.d_model * (a.q_dim + 2 * a.kv_dim)
                      + a.q_dim * self.d_model
                      + 3 * self.d_model * self.shared_attn_d_ff
                      + 2 * self.d_model)
        return total

    def _layer_params(self, kind: str) -> int:
        D, F = self.d_model, self.d_ff
        if kind in ("dense", "local", "encoder", "dense_moe"):
            a = self.attn
            attn = D * (a.q_dim + 2 * a.kv_dim) + a.q_dim * D
            mlp = 3 * D * F
            return attn + mlp + 2 * D
        if kind == "moe":
            a, m = self.attn, self.moe
            attn = D * (a.q_dim + 2 * a.kv_dim) + a.q_dim * D
            ff = m.n_experts * 3 * D * m.d_ff_expert + D * m.n_experts
            if m.shared_expert:
                ff += 3 * D * m.d_ff_expert
            return attn + ff + 2 * D
        if kind == "hybrid_par":
            # Falcon-H1/Hymba-style parallel heads: attention + SSM side by
            # side in the same layer, then an MLP.
            a, s = self.attn, self.ssm
            di = s.d_inner(D)
            ng, ns = s.n_groups, s.d_state
            nh = s.n_ssm_heads(D)
            conv_dim = di + 2 * ng * ns
            attn = D * (a.q_dim + 2 * a.kv_dim) + a.q_dim * D
            mamba = (D * (2 * di + 2 * ng * ns + nh) + conv_dim * s.conv_kernel
                     + nh * 3 + di + di * D)
            return attn + mamba + 3 * D * F + 2 * D
        if kind in ("mamba2", "mamba2+shared", "mamba1"):
            s = self.ssm
            di = s.d_inner(D)
            if s.variant == "mamba2" or kind.startswith("mamba2"):
                ng, ns = s.n_groups, s.d_state
                nh = s.n_ssm_heads(D)
                conv_dim = di + 2 * ng * ns
                return (D * (2 * di + 2 * ng * ns + nh)   # in_proj
                        + conv_dim * s.conv_kernel         # conv1d
                        + nh * 3                           # A_log, D, dt_bias
                        + di                               # gated norm
                        + di * D + D)                      # out_proj + layer norm
            # mamba1
            dtr = s.dt_rank or max(1, math.ceil(D / 16))
            return (D * 2 * di + di * s.conv_kernel + di
                    + di * (dtr + 2 * s.d_state) + dtr * di
                    + di * s.d_state + di + di * D + D)
        raise ValueError(f"unknown layer kind {kind!r}")

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        m = self.moe
        n_moe_layers = sum(1 for k in self.layer_kinds if k == "moe")
        dead = (m.n_experts - m.experts_per_token) * 3 * self.d_model * m.d_ff_expert
        return total - n_moe_layers * dead


@dataclass(frozen=True)
class WorkloadConfig:
    """One characterization cell: what step is lowered at which shape."""
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    gen_len: int = 1     # decode: number of generated tokens modeled
    dtype: str = "bfloat16"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four canonical shapes from the assignment.
TRAIN_4K = WorkloadConfig("train_4k", "train", seq_len=4096, global_batch=256)
PREFILL_32K = WorkloadConfig("prefill_32k", "prefill", seq_len=32768, global_batch=32)
DECODE_32K = WorkloadConfig("decode_32k", "decode", seq_len=32768, global_batch=128)
LONG_500K = WorkloadConfig("long_500k", "decode", seq_len=524288, global_batch=1)
SHAPES = {w.name: w for w in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class HardwareSpec:
    """Per-chip capability used by roofline/energy models."""
    name: str
    peak_flops: float          # FLOP/s at the benchmark dtype
    hbm_bw: float              # bytes/s
    hbm_bytes: float           # capacity
    link_bw: float = 0.0       # bytes/s per ICI/NVLink link
    power_w: float = 0.0       # sustained board power for the energy model
    idle_w: float = 0.0

    def time_compute(self, flops: float) -> float:
        return flops / self.peak_flops

    def time_memory(self, bytes_: float) -> float:
        return bytes_ / self.hbm_bw


TPU_V5E = HardwareSpec("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                       hbm_bytes=16e9, link_bw=50e9, power_w=170.0, idle_w=60.0)
RTX_4090 = HardwareSpec("rtx4090", peak_flops=165e12, hbm_bw=1008e9,
                        hbm_bytes=24e9, link_bw=32e9, power_w=450.0, idle_w=30.0)
JETSON_ORIN_NANO = HardwareSpec("jetson_orin_nano", peak_flops=20e12, hbm_bw=68e9,
                                hbm_bytes=8e9, link_bw=0.0, power_w=15.0, idle_w=5.0)
HARDWARE = {h.name: h for h in (TPU_V5E, RTX_4090, JETSON_ORIN_NANO)}
