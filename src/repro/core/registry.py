"""Model registry (paper Fig. 4): arch id -> ModelConfig (+ tags).

New models are added with :func:`register`; the assigned-architecture pool
self-registers on import of ``repro.configs``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from repro.core.config import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}
_TAGS: Dict[str, tuple] = {}


def register(cfg: ModelConfig, tags: Iterable[str] = ()) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    _TAGS[cfg.name] = tuple(tags)
    return cfg


def get(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs(tag: Optional[str] = None) -> List[str]:
    _ensure_loaded()
    if tag is None:
        return sorted(_REGISTRY)
    return sorted(n for n, t in _TAGS.items() if tag in t)


def tags_of(name: str) -> tuple:
    _ensure_loaded()
    return _TAGS.get(name, ())


def _ensure_loaded() -> None:
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (self-registers)
