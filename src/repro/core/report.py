"""Report emission: CSV rows and markdown tables for EXPERIMENTS.md."""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Sequence


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}TB"


def fmt_si(x: float, suffix: str = "") -> str:
    for scale, p in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if abs(x) >= scale:
            return f"{x / scale:.2f}{p}{suffix}"
    return f"{x:.2f}{suffix}"


def fmt_time(t: float) -> str:
    if t >= 1.0:
        return f"{t:.2f}s"
    if t >= 1e-3:
        return f"{t * 1e3:.2f}ms"
    return f"{t * 1e6:.1f}us"


def markdown_table(headers: Sequence[str], rows: Iterable[Sequence]) -> str:
    lines = ["| " + " | ".join(headers) + " |",
             "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(lines)


def csv_lines(headers: Sequence[str], rows: Iterable[Sequence]) -> List[str]:
    out = [",".join(headers)]
    for r in rows:
        out.append(",".join(str(c) for c in r))
    return out


def save_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=str)


def load_json(path: str):
    with open(path) as f:
        return json.load(f)
