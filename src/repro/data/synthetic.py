"""Synthetic long-context data pipeline (BABILong-style needle retrieval).

Restart-deterministic by construction: batch(step) is a pure function of
(seed, step), so resuming from a checkpoint at step k replays the exact
stream — the data-side half of fault tolerance.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    needle_len: int = 8        # copy-task needle planted in the haystack
    needle_offset_frac: float = 0.5


class SyntheticLM:
    """Needle-in-a-haystack token stream: random haystack, a needle span is
    planted, and repeated near the end — the LM must retrieve across long
    context (the paper's motivating workload)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([c.seed, step]))
        toks = rng.integers(2, c.vocab_size,
                            size=(c.global_batch, c.seq_len), dtype=np.int64)
        nl = min(c.needle_len, max(c.seq_len // 8, 1))
        ins = int(c.seq_len * c.needle_offset_frac * 0.5)
        rep = max(c.seq_len - 2 * nl - 1, ins + nl)
        needle = rng.integers(2, c.vocab_size,
                              size=(c.global_batch, nl), dtype=np.int64)
        toks[:, ins:ins + nl] = needle
        toks[:, rep:rep + nl] = needle          # retrieval target
        toks[:, rep - 1] = 1                    # "recall" marker token
        return {"tokens": toks.astype(np.int32),
                "labels": toks.astype(np.int32)}

    def iter(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class SyntheticAudio:
    """Frame-feature stream for the [audio] stub frontend."""

    def __init__(self, cfg: DataConfig, feat_dim: int = 512):
        self.cfg = cfg
        self.feat_dim = feat_dim

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([c.seed, step, 7]))
        feats = rng.standard_normal(
            (c.global_batch, c.seq_len, self.feat_dim)).astype(np.float32)
        labels = rng.integers(0, c.vocab_size,
                              size=(c.global_batch, c.seq_len), dtype=np.int64)
        return {"features": feats, "labels": labels.astype(np.int32)}


def needle_accuracy(pred: np.ndarray, batch: Dict[str, np.ndarray],
                    cfg: DataConfig) -> float:
    """Fraction of needle-repeat tokens predicted correctly (retrieval metric)."""
    nl = min(cfg.needle_len, max(cfg.seq_len // 8, 1))
    rep = max(cfg.seq_len - 2 * nl - 1, 0)
    tgt = batch["labels"][:, rep:rep + nl]
    got = pred[:, rep - 1:rep + nl - 1] if rep >= 1 else pred[:, :nl]
    return float((tgt == got).mean())
