"""Byte-level tokenizer (examples/serving demos; no external vocab files).

ids 0..255 = bytes; 256 = BOS; 257 = EOS; 258 = PAD.
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

BOS, EOS, PAD = 256, 257, 258
VOCAB_SIZE = 259


def encode(text: str, *, bos: bool = True, eos: bool = False) -> np.ndarray:
    ids: List[int] = list(text.encode("utf-8"))
    if bos:
        ids = [BOS] + ids
    if eos:
        ids = ids + [EOS]
    return np.asarray(ids, np.int32)


def decode(ids: Iterable[int]) -> str:
    bs = bytes(i for i in ids if 0 <= int(i) < 256)
    return bs.decode("utf-8", errors="replace")


def batch_encode(texts: List[str], *, pad_to: int) -> np.ndarray:
    rows = []
    for t in texts:
        ids = encode(t)[:pad_to]
        rows.append(np.pad(ids, (0, pad_to - len(ids)),
                           constant_values=PAD))
    return np.stack(rows)
