"""Policy-driven request scheduling for the serving engine.

The paper's operational claim — SSM/hybrid models win on-device at long
context — only matters if the serving layer can arbitrate many
concurrent long-context requests under latency SLOs.  Session-style
workloads mix latency-critical short turns with background long-context
prefills; arbitrating that mix is a *policy* question (who goes first,
who gets evicted, who may starve), and policy used to be fused into
``ServingEngine`` as deadline-slack ordering only.

This module is the extracted policy layer.  The engine keeps the
*mechanism* — dispatching compiled programs, offloading/restoring slots,
moving requests to terminal states — and delegates every scheduling
*decision* to a :class:`Scheduler`:

* **admission order** — which queued requests (fresh prompts and
  preempted restores alike) are considered first when slots free up;
* **preemption** — whether starvation warrants evicting a live slot now
  (:meth:`Scheduler.urgent_preempt`) and which slot to evict
  (:meth:`Scheduler.preempt_victim` over slack-costed candidates);
* **expiry** — whether a request's deadline has burned out
  (:meth:`Scheduler.expired`) and whether a queued request has waited
  past the policy's starvation bound (:meth:`Scheduler.starved_out` —
  failed with :class:`repro.serving.faults.StarvationTimeout`);
* **interleave share** — what fraction of engine iterations the
  in-flight prefill group may claim next to live decode slots
  (:meth:`Scheduler.interleave_share`).

Requests carry a small non-negative integer ``priority`` *class*
(higher = more important; default 0).  Three policies ship:

``fifo``
    Submit order, slack-based preemption victims, no starvation bound —
    byte-for-byte the engine's pre-scheduler behaviour, and the default.

``strict_tiers``
    Higher classes always go first; a queued request of a strictly
    higher class triggers immediate preemption of the lowest-class live
    slot.  Strictness means a low class can wait forever under sustained
    high-class load, so the ``starve_ms`` bound converts unbounded
    waiting into a structured ``StarvationTimeout`` failure.

``weighted_fair``
    Deficit-round-robin token accounting: a deficit round — fired only
    once every class with queued work has exhausted its credit — banks
    ``quantum x weight`` tokens per class, and every prefill/decode
    token processed debits its class
    (:meth:`Scheduler.note_service`).  Admission favours the class with
    the most credit, so long-run prefill+decode throughput tracks the
    configured weights; preemption evicts the class most *over* its
    share.  A queued request older than ``starve_ms`` is escalated to
    the front regardless of class (aging), so the starvation bound is
    honoured by service rather than by failure.

The invariant that makes all of this safe: policies reorder WORK, never
math.  Decode rows are independent across the batch dimension in every
kernel and preemption blobs restore bit-exactly, so any individual
request's decoded tokens are bit-identical under every policy (asserted
in ``tests/test_scheduler.py`` and the ``scheduling`` smoke gate).

Configuration: ``REPRO_SCHED_POLICY`` selects the policy,
``REPRO_SCHED_WEIGHTS`` sets per-class weights as ``class:weight`` pairs
(``"0:1,1:4,2:16"``); both are read by :func:`make_scheduler` (once, at
engine construction) and overridable per engine.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

#: policies make_scheduler accepts (the REPRO_SCHED_POLICY vocabulary)
POLICIES = ("fifo", "strict_tiers", "weighted_fair")

#: DRR credit quantum (tokens per deficit round per unit weight)
DEFAULT_QUANTUM = 64


@dataclass(frozen=True)
class VictimCandidate:
    """One live slot offered to :meth:`Scheduler.preempt_victim`.

    ``slack`` is the engine-estimated deadline margin in ms (infinite
    for deadline-less requests) under the telemetry latency model —
    cost estimation is mechanism and stays in the engine; the policy
    only ranks the candidates."""

    slot: int
    priority: int
    slack: float
    remaining: int


def parse_weights(spec: Optional[str]) -> Dict[int, float]:
    """Parse a ``REPRO_SCHED_WEIGHTS`` string (``"0:1,1:4,2:16"``) into a
    ``{class: weight}`` dict.  Empty/None -> {} (every class weighs 1).
    Malformed entries raise ``ValueError`` naming the offending pair —
    a silently dropped weight would skew fairness without a trace."""
    if not spec:
        return {}
    out: Dict[int, float] = {}
    for pair in spec.split(","):
        pair = pair.strip()
        if not pair:
            continue
        cls_s, sep, w_s = pair.partition(":")
        try:
            if not sep:
                raise ValueError("missing ':'")
            cls, w = int(cls_s), float(w_s)
            if cls < 0 or w <= 0:
                raise ValueError("class must be >= 0 and weight > 0")
        except ValueError as e:
            raise ValueError(
                f"REPRO_SCHED_WEIGHTS entry {pair!r} is malformed "
                f"(want 'class:weight', e.g. '1:4'): {e}") from None
        out[cls] = w
    return out


class Scheduler:
    """Base policy: FIFO admission, slack-based preemption, deadline
    expiry, no starvation bound, full prefill interleave.  This IS the
    ``fifo`` policy — subclasses override only the decisions they
    change, so the fifo rows below double as the protocol's defaults."""

    policy = "fifo"

    def __init__(self, weights: Optional[Dict[int, float]] = None,
                 starve_ms: Optional[float] = None):
        self.weights = dict(weights or {})
        self.starve_ms = starve_ms
        self._served: Dict[int, float] = {}

    # ------------------------------------------------------------- helpers
    def weight(self, priority: int) -> float:
        return self.weights.get(priority, 1.0)

    def wait_ms(self, req, now: float) -> float:
        return (now - req.submit_t) * 1e3

    # ----------------------------------------------------------- decisions
    def admission_order(self, queue: Sequence, now: float) -> List:
        """Queued requests (fresh + preempted restores) in the order the
        engine should admit them.  Must be a permutation of ``queue``."""
        return list(queue)

    def expired(self, req, now: float) -> bool:
        """Has this request's deadline TTL burned out?"""
        return (req.deadline_ms is not None
                and self.wait_ms(req, now) > req.deadline_ms)

    def starved_out(self, queue: Sequence, live: Sequence,
                    now: float) -> List:
        """Queued requests the policy gives up on (the engine fails them
        with ``StarvationTimeout``).  Default: never — FIFO head-of-line
        order cannot starve, and weighted_fair escalates instead."""
        return []

    def urgent_preempt(self, queue: Sequence, live: Sequence) -> bool:
        """Should the engine preempt NOW, without waiting out its
        ``preempt_after`` starvation counter?"""
        return False

    def preempt_victim(self, candidates: Sequence[VictimCandidate],
                       queue: Sequence) -> Optional[int]:
        """Slot to evict for a starved queue (None = nobody).  Default:
        most deadline slack, ties broken on max remaining decode work —
        deadline-less slots rank as infinite slack."""
        best: Optional[Tuple[Tuple[float, int], int]] = None
        for c in candidates:
            key = (c.slack, c.remaining)
            if best is None or key > best[0]:
                best = (key, c.slot)
        return None if best is None else best[1]

    def interleave_share(self, group_classes: Sequence[int],
                         live_classes: Sequence[int]) -> float:
        """Fraction of engine iterations the in-flight prefill group may
        claim (1.0 = one chunk every iteration, the historical
        behaviour).  The engine clamps to (0, 1] and always runs the
        chunk when no slot is decoding."""
        return 1.0

    # ---------------------------------------------------------- accounting
    def note_service(self, priority: int, tokens: int) -> None:
        """Record ``tokens`` of prefill/decode service for a class (the
        DRR debit hook; base policies only keep the served totals the
        fairness benchmarks read)."""
        if tokens:
            self._served[priority] = self._served.get(priority, 0.0) + tokens

    def class_service(self) -> Dict[int, float]:
        """Tokens served per priority class since construction."""
        return dict(self._served)


class StrictTiersScheduler(Scheduler):
    """Strict priority tiers: a higher class always outranks a lower one.

    Admission sorts by class (descending, submit order within a class),
    a queued request of a strictly higher class triggers immediate
    preemption, and the victim is always the lowest-class live slot
    (slack-ranked within the class).  Strictness is honest about its
    cost: under sustained high-class load a low-class request waits
    unboundedly, so ``starve_ms`` fails outranked waiters with
    ``StarvationTimeout`` instead of letting them rot invisibly."""

    policy = "strict_tiers"

    def admission_order(self, queue: Sequence, now: float) -> List:
        return sorted(queue, key=lambda r: -r.priority)   # stable

    def starved_out(self, queue: Sequence, live: Sequence,
                    now: float) -> List:
        if self.starve_ms is None:
            return []
        classes = [r.priority for r in queue] + \
                  [r.priority for r in live if r is not None]
        top = max(classes, default=0)
        return [r for r in queue
                if r.priority < top and self.wait_ms(r, now) > self.starve_ms]

    def urgent_preempt(self, queue: Sequence, live: Sequence) -> bool:
        live_cls = [r.priority for r in live if r is not None]
        if not queue or not live_cls:
            return False
        return max(r.priority for r in queue) > min(live_cls)

    def preempt_victim(self, candidates: Sequence[VictimCandidate],
                       queue: Sequence) -> Optional[int]:
        if not candidates:
            return None
        top_queued = max((r.priority for r in queue), default=0)
        low = min(c.priority for c in candidates)
        if top_queued <= low:
            # never evict a slot for an equal-or-lower class: strict
            # tiers preempt upward only, equal classes wait their turn
            return None
        pool = [c for c in candidates if c.priority == low]
        return super().preempt_victim(pool, queue)

    def interleave_share(self, group_classes: Sequence[int],
                         live_classes: Sequence[int]) -> float:
        # a lower-class group prefilling next to higher-class decode
        # slots yields half its iterations to decode latency
        if not group_classes or not live_classes:
            return 1.0
        return 1.0 if max(group_classes) >= max(live_classes) else 0.5


class WeightedFairScheduler(Scheduler):
    """Weighted fairness via deficit-round-robin token accounting.

    Each class carries a deficit counter.  A *round* fires only when
    every class with queued work has exhausted its credit (<= 0); the
    round then banks ``quantum x weight(class)`` on top of the residual
    deficit, exactly DRR.  Crediting only on exhaustion is what makes
    the accounting converge: a per-call unconditional credit would pin
    high-weight classes at their cap and degenerate into strict tiers.
    Every prefill/decode token the engine processes debits its class
    (:meth:`note_service`).  Admission order is by credit (most
    under-served first), so sustained-backlog throughput converges to
    the weight ratios — the Jain-fairness gate in the ``scheduling``
    smoke measures exactly this.  ``starve_ms`` is an aging bound: a
    request waiting longer jumps the entire order whatever its class,
    so low-weight classes are late, never starved."""

    policy = "weighted_fair"

    def __init__(self, weights: Optional[Dict[int, float]] = None,
                 starve_ms: Optional[float] = None,
                 quantum: int = DEFAULT_QUANTUM):
        super().__init__(weights, starve_ms)
        self.quantum = int(quantum)
        self._credit: Dict[int, float] = {}

    def _credit_round(self, classes) -> None:
        present = set(classes)
        if present and all(self._credit.get(c, 0.0) <= 0.0
                           for c in present):
            for c in present:
                self._credit[c] = (self._credit.get(c, 0.0)
                                   + self.quantum * self.weight(c))

    def admission_order(self, queue: Sequence, now: float) -> List:
        self._credit_round(r.priority for r in queue)
        if self.starve_ms is not None:
            aged = [r for r in queue
                    if self.wait_ms(r, now) > self.starve_ms]
            if aged:
                aged_set = {id(r) for r in aged}
                aged.sort(key=lambda r: -self.wait_ms(r, now))
                rest = [r for r in queue if id(r) not in aged_set]
                rest.sort(key=lambda r: -self._credit.get(r.priority, 0.0))
                return aged + rest
        return sorted(queue,
                      key=lambda r: -self._credit.get(r.priority, 0.0))

    def note_service(self, priority: int, tokens: int) -> None:
        super().note_service(priority, tokens)
        if tokens:
            self._credit[priority] = \
                self._credit.get(priority, 0.0) - tokens

    def preempt_victim(self, candidates: Sequence[VictimCandidate],
                       queue: Sequence) -> Optional[int]:
        if not candidates:
            return None
        # evict the class furthest OVER its weighted share (most-negative
        # normalized credit); slack-rank within that class
        def over_share(c: VictimCandidate) -> float:
            return -self._credit.get(c.priority, 0.0) / self.weight(c.priority)
        worst = max(over_share(c) for c in candidates)
        pool = [c for c in candidates if over_share(c) == worst]
        return super().preempt_victim(pool, queue)

    def interleave_share(self, group_classes: Sequence[int],
                         live_classes: Sequence[int]) -> float:
        if not group_classes or not live_classes:
            return 1.0
        g = sum(self.weight(c) for c in group_classes)
        l = sum(self.weight(c) for c in live_classes)
        return max(0.25, min(1.0, g / (g + l) * 2.0))


_POLICY_CLASSES = {
    "fifo": Scheduler,
    "strict_tiers": StrictTiersScheduler,
    "weighted_fair": WeightedFairScheduler,
}


def make_scheduler(policy: Optional[str] = None,
                   weights: Optional[Dict[int, float]] = None,
                   starve_ms: Optional[float] = None) -> Scheduler:
    """Build a scheduler from explicit arguments, falling back to the
    ``REPRO_SCHED_POLICY`` / ``REPRO_SCHED_WEIGHTS`` environment (read
    here, once per engine construction).  Unknown policies raise — a
    typo'd policy silently degrading to FIFO would be unobservable."""
    policy = policy or os.environ.get("REPRO_SCHED_POLICY") or "fifo"
    if policy not in _POLICY_CLASSES:
        raise ValueError(
            f"unknown scheduling policy {policy!r}: expected one of "
            f"{POLICIES} (set via REPRO_SCHED_POLICY or the engine's "
            "sched_policy argument)")
    if weights is None:
        weights = parse_weights(os.environ.get("REPRO_SCHED_WEIGHTS"))
    return _POLICY_CLASSES[policy](weights=weights, starve_ms=starve_ms)
