"""Structured serving telemetry: the per-(arch, phase, KV-bucket) latency
model, per-request span traces, and static operator-level cost
attribution.

The paper's core contribution is *operator-level* characterization —
selective-scan kernels account for >55% of edge-inference latency, and
the Transformer/SSM crossover only shows up when time is attributed per
phase and per sequence-length regime.  Collapsing the serving engine's
timing into two scalar EWMAs loses exactly that structure, and worse:
a first dispatch into a fresh KV bucket pays trace+compile, so an
unguarded sample poisons the steady-state estimate that deadline
admission and preemption-victim selection depend on — one bucket-ladder
climb could spuriously time out every queued request.

This module replaces the scalars with three layers:

* **Latency table** — :class:`TelemetryTable`, one
  :class:`PhaseBucketStats` per ``(arch, phase, kv_bucket)`` key
  (arch = the model config name, so one table can serve several configs
  without mixing their rungs; phases: ``prefill`` / ``decode``; bucket =
  the static KV rung the compiled program ran under, ``None`` for
  architectures without a KV cache).  Each entry keeps TWO
  :class:`LatencyRecord` s — ``steady`` and ``compile`` — so
  first-dispatch samples are *segregated*, never discarded: the compile
  record is observability (how much a ladder climb costs), the steady
  record is the only one feeding scheduling.  :meth:`Telemetry.estimate`
  answers "expected ms/token for this phase at this bucket" from the
  bucket's steady record, falling back to the phase-global steady record
  *within the same arch* — never across archs.  The table round-trips
  through a versioned JSON blob (:meth:`TelemetryTable.save` /
  :meth:`TelemetryTable.load`), so a new engine warm-starts deadline
  admission and preemption slack from a previous run's measured model
  (``REPRO_TELEMETRY_WARMSTART``) instead of cold scalars; corrupt or
  version-mismatched blobs are rejected with a logged warning and the
  table stays cold.
* **Span traces** — per-request event timelines (queued -> prefill
  chunks -> decode bursts -> terminal state, with bucket, preemption,
  checkpoint, replay and fault events).  Consecutive same-phase
  same-bucket events coalesce (a 1000-burst decode is one event with
  ``bursts``/``tokens`` counters, split whenever the bucket climbs), so
  spans stay O(ladder rungs), not O(tokens).  When ``REPRO_TRACE_PATH``
  is set (or ``trace_path`` is passed), each finished span is appended
  to that file as one JSON line carrying ``version`` + ``arch``;
  :func:`read_trace` rejects lines written by an incompatible schema.
* **Operator attribution** — :func:`operator_costs` maps a compiled XLA
  program to flop/byte totals (via the version-portable
  :func:`repro.core.hlo_analysis.xla_cost_dict`) plus per-kernel-family
  shares (gemm / ssm / norm / memory / arith / collective) from the
  trip-count-corrected HLO walk — the paper's Table-style operator
  breakdown, derived statically so benchmarks can report it without a
  profiler.  The *measured* counterpart lives in
  :mod:`repro.serving.profiler`.

All timestamps come from the injected ``clock`` (the engine passes its
own, so fault-injection tests with a fake clock see one consistent time
base across deadlines, latency samples and trace spans).
"""
from __future__ import annotations

import json
import logging
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

log = logging.getLogger("repro.serving.telemetry")

# phases a latency key may carry (order = pipeline order)
PHASES = ("prefill", "decode")

#: schema version for trace JSONL lines AND latency snapshots; bumped to 2
#: when the table became arch-keyed (v1 lines have no arch and would be
#: misattributed — read_trace rejects them)
TRACE_SCHEMA_VERSION = 2

#: schema version of the warm-start blob (arch-keyed table serialization)
TELEMETRY_BLOB_VERSION = 1

#: arch key used when the caller never names one (single-config benches)
DEFAULT_ARCH = "default"


@dataclass
class LatencyRecord:
    """EWMA + count + min/max over per-token latency samples (ms)."""

    ewma_ms: float = 0.0
    count: int = 0
    min_ms: float = float("inf")
    max_ms: float = 0.0

    def observe(self, ms: float, alpha: float) -> None:
        self.ewma_ms = ms if self.count == 0 \
            else alpha * ms + (1.0 - alpha) * self.ewma_ms
        self.count += 1
        self.min_ms = min(self.min_ms, ms)
        self.max_ms = max(self.max_ms, ms)

    def as_dict(self) -> Dict[str, Any]:
        return {"ewma_ms": self.ewma_ms, "count": self.count,
                "min_ms": None if self.count == 0 else self.min_ms,
                "max_ms": None if self.count == 0 else self.max_ms}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "LatencyRecord":
        count = int(d.get("count", 0))
        return cls(ewma_ms=float(d.get("ewma_ms", 0.0)), count=count,
                   min_ms=(float("inf") if d.get("min_ms") is None
                           else float(d["min_ms"])),
                   max_ms=float(d.get("max_ms") or 0.0))


@dataclass
class PhaseBucketStats:
    """Latency for one (arch, phase, kv_bucket) key: steady-state samples
    and first-dispatch (trace+compile) samples, segregated — only
    ``steady`` ever feeds admission/preemption estimates."""

    steady: LatencyRecord = field(default_factory=LatencyRecord)
    compile: LatencyRecord = field(default_factory=LatencyRecord)

    def as_dict(self) -> Dict[str, Any]:
        return {"steady": self.steady.as_dict(),
                "compile": self.compile.as_dict()}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PhaseBucketStats":
        return cls(steady=LatencyRecord.from_dict(d.get("steady", {})),
                   compile=LatencyRecord.from_dict(d.get("compile", {})))


def _bucket_key(bucket: Optional[int]) -> int:
    # None (no KV cache / bucketing off) keys as -1 so the table stays
    # JSON-sortable; the phase-global aggregate lives under GLOBAL_KEY
    return -1 if bucket is None else int(bucket)


GLOBAL_KEY = "*"


def _parse_key(s: str):
    return GLOBAL_KEY if s == GLOBAL_KEY else int(s)


class TelemetryTable:
    """The per-(arch, phase, kv_bucket) latency table, shareable across
    several :class:`Telemetry` fronts (one engine per arch) and
    persistable as a versioned JSON blob for cross-process warm starts.
    """

    def __init__(self, alpha: float = 0.25):
        self.alpha = float(alpha)
        # {(arch, phase, bucket_key) -> PhaseBucketStats}; bucket
        # GLOBAL_KEY is the per-(arch, phase) aggregate estimates fall
        # back to — never across archs
        self._lat: Dict[Tuple[str, str, Any], PhaseBucketStats] = {}

    def _entry(self, arch: str, phase: str, key) -> PhaseBucketStats:
        if (arch, phase, key) not in self._lat:
            self._lat[(arch, phase, key)] = PhaseBucketStats()
        return self._lat[(arch, phase, key)]

    def record(self, arch: str, phase: str, bucket: Optional[int],
               tok_ms: float, *, compiled: bool = False) -> None:
        for key in (_bucket_key(bucket), GLOBAL_KEY):
            rec = self._entry(arch, phase, key)
            (rec.compile if compiled else rec.steady).observe(
                tok_ms, self.alpha)

    def estimate(self, arch: str, phase: str,
                 bucket: Optional[int]) -> Optional[float]:
        for key in (_bucket_key(bucket), GLOBAL_KEY):
            rec = self._lat.get((arch, phase, key))
            if rec is not None and rec.steady.count > 0:
                return rec.steady.ewma_ms
        return None

    def archs(self) -> List[str]:
        return sorted({arch for (arch, _, _) in self._lat})

    def snapshot(self, arch: str) -> Dict[str, Dict[str, Any]]:
        """One arch's slice as ``{"decode@256": {...}, ...}``."""
        return {f"{phase}@{key}": rec.as_dict()
                for (a, phase, key), rec in sorted(
                    self._lat.items(),
                    key=lambda kv: (kv[0][0], kv[0][1], str(kv[0][2])))
                if a == arch}

    # ------------------------------------------------------- persistence
    def as_blob(self) -> Dict[str, Any]:
        archs: Dict[str, Dict[str, Any]] = {}
        for (arch, phase, key), rec in self._lat.items():
            archs.setdefault(arch, {})[f"{phase}@{key}"] = rec.as_dict()
        return {"version": TELEMETRY_BLOB_VERSION, "alpha": self.alpha,
                "archs": {a: dict(sorted(v.items()))
                          for a, v in sorted(archs.items())}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.as_blob(), f, indent=1)
        return path

    def load(self, path: str) -> int:
        """Merge a saved blob into this table (saved entries overwrite
        same-key entries).  Raises ``ValueError`` on corrupt JSON, a
        structurally invalid blob, or a version mismatch — callers log
        and stay cold.  Returns the number of entries loaded."""
        try:
            with open(path) as f:
                blob = json.load(f)
        except (OSError, ValueError) as e:
            raise ValueError(
                f"telemetry warm-start blob {path!r} unreadable: {e}")
        if not isinstance(blob, dict):
            raise ValueError(
                f"telemetry warm-start blob {path!r}: expected an object, "
                f"got {type(blob).__name__}")
        version = blob.get("version")
        if version != TELEMETRY_BLOB_VERSION:
            raise ValueError(
                f"telemetry warm-start blob {path!r} has version "
                f"{version!r}, expected {TELEMETRY_BLOB_VERSION}")
        archs = blob.get("archs")
        if not isinstance(archs, dict):
            raise ValueError(
                f"telemetry warm-start blob {path!r}: missing 'archs'")
        loaded = 0
        try:
            for arch, table in archs.items():
                for pk, rec in table.items():
                    phase, _, key_s = pk.partition("@")
                    self._lat[(arch, phase, _parse_key(key_s))] = \
                        PhaseBucketStats.from_dict(rec)
                    loaded += 1
        except (AttributeError, KeyError, TypeError, ValueError) as e:
            raise ValueError(
                f"telemetry warm-start blob {path!r} malformed: {e}")
        return loaded


class Telemetry:
    """Metrics + tracing front for one :class:`ServingEngine` (or bench),
    bound to one ``arch`` over a (possibly shared) :class:`TelemetryTable`.

    ``clock`` is the time base (seconds); ``alpha`` the EWMA smoothing
    factor shared by every record; ``trace_path`` enables JSONL span
    export (defaults to the ``REPRO_TRACE_PATH`` env var, read once at
    construction); ``warmstart_path`` (default: the
    ``REPRO_TELEMETRY_WARMSTART`` env var) names a blob to load at
    construction — if it exists — and to save via
    :meth:`save_warmstart`.  A bad blob logs a warning and leaves the
    table cold; it never raises out of the constructor.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 alpha: float = 0.25,
                 trace_path: Optional[str] = None,
                 arch: str = DEFAULT_ARCH,
                 table: Optional[TelemetryTable] = None,
                 warmstart_path: Optional[str] = None):
        import time
        self._clock = clock or time.monotonic
        self.arch = arch
        self.table = table if table is not None else TelemetryTable(alpha)
        self.alpha = self.table.alpha
        self.trace_path = (trace_path if trace_path is not None
                           else os.environ.get("REPRO_TRACE_PATH") or None)
        self.warmstart_path = (
            warmstart_path if warmstart_path is not None
            else os.environ.get("REPRO_TELEMETRY_WARMSTART") or None)
        self.warmstart_loaded = False
        if self.warmstart_path and os.path.exists(self.warmstart_path):
            try:
                n = self.table.load(self.warmstart_path)
            except ValueError as e:
                log.warning("telemetry warm-start rejected (cold start): %s",
                            e)
            else:
                self.warmstart_loaded = True
                log.info("telemetry warm-start: %d entries from %s",
                         n, self.warmstart_path)
        self._spans: Dict[int, Dict[str, Any]] = {}    # rid -> open span
        self.finished_spans: List[Dict[str, Any]] = []

    # ------------------------------------------------------- latency table
    def record_latency(self, phase: str, bucket: Optional[int],
                       tok_ms: float, *, compiled: bool = False) -> None:
        """One per-token latency sample for ``phase`` under ``bucket``.
        ``compiled=True`` marks a first-dispatch (trace+compile) sample:
        it lands in the segregated compile record and NEVER moves the
        steady-state estimate."""
        self.table.record(self.arch, phase, bucket, tok_ms,
                          compiled=compiled)

    def estimate(self, phase: str, bucket: Optional[int]) -> Optional[float]:
        """Steady-state ms/token for this arch's ``phase`` at ``bucket``;
        falls back to the same arch's phase-global steady record when the
        bucket is unmeasured; None when the phase has no steady samples
        at all.  Never reads another arch's rungs."""
        return self.table.estimate(self.arch, phase, bucket)

    def latency_snapshot(self) -> Dict[str, Any]:
        """JSON-able view of this arch's slice of the table:
        ``{"version": 2, "arch": ..., "table": {"decode@256": {...},
        ...}}`` (``@*`` = phase-global aggregate, ``@-1`` =
        unbucketed)."""
        return {"version": TRACE_SCHEMA_VERSION, "arch": self.arch,
                "table": self.table.snapshot(self.arch)}

    def save_warmstart(self, path: Optional[str] = None) -> Optional[str]:
        """Persist the (shared) table for the next process; returns the
        path written, or None when no path is configured."""
        path = path or self.warmstart_path
        if not path:
            return None
        return self.table.save(path)

    # -------------------------------------------------------- span traces
    def begin_span(self, rid: int, *, prompt_len: int, max_new: int,
                   deadline_ms: Optional[float] = None,
                   priority: int = 0, t: Optional[float] = None,
                   **fields: Any) -> None:
        """Open ``rid``'s span.  Extra ``fields`` land on the span record
        verbatim — the engine's restart-recovery path stamps
        ``rehydrated=<outcome>`` so a resumed request's trace says it
        crossed a process boundary (its ``submit_t`` is back-dated to
        preserve the deadline budget already consumed)."""
        self._spans[rid] = {
            "version": TRACE_SCHEMA_VERSION, "arch": self.arch,
            "rid": rid, "submit_t": self._clock() if t is None else t,
            "prompt_len": int(prompt_len), "max_new": int(max_new),
            "deadline_ms": deadline_ms, "priority": int(priority),
            "status": "pending", "events": [], **fields}

    def first_token(self, rid: int) -> Optional[float]:
        """Mark ``rid``'s first emitted token and return its TTFT in ms
        (clock now minus span submit time).  Idempotent — a request
        restored after preemption already has its TTFT and keeps it; a
        no-op (None) for unknown rids."""
        span = self._spans.get(rid)
        if span is None:
            return None
        if "ttft_ms" not in span:
            span["ttft_ms"] = (self._clock() - span["submit_t"]) * 1e3
        return span["ttft_ms"]

    # repeated same-(kind, bucket) events merge into one counting event:
    # spans scale with bucket climbs and phase changes, not token counts
    _COALESCE = {"prefill": "chunks", "decode": "bursts",
                 "checkpoint": "count"}

    def event(self, rid: int, kind: str, *, bucket: Optional[int] = None,
              tokens: int = 0, **fields: Any) -> None:
        """Append one event to ``rid``'s span (no-op for unknown rids, so
        bench/test callers need no span bookkeeping).  ``prefill`` /
        ``decode`` / ``checkpoint`` events coalesce with the previous
        event when the kind AND bucket match."""
        span = self._spans.get(rid)
        if span is None:
            return
        ev: Dict[str, Any] = {"t": self._clock(), "kind": kind}
        if bucket is not None:
            ev["bucket"] = int(bucket)
        unit = self._COALESCE.get(kind)
        if unit is not None:
            prev = span["events"][-1] if span["events"] else None
            if (prev is not None and prev["kind"] == kind
                    and prev.get("bucket") == ev.get("bucket")):
                prev[unit] += 1
                if kind != "checkpoint":
                    prev["tokens"] += int(tokens)
                prev["t_last"] = ev["t"]
                return
            ev[unit] = 1
            if kind != "checkpoint":
                ev["tokens"] = int(tokens)
        ev.update(fields)
        span["events"].append(ev)

    def end_span(self, rid: int, status: str, *,
                 error: Optional[str] = None, tokens_out: int = 0) -> None:
        span = self._spans.pop(rid, None)
        if span is None:
            return
        span["status"] = status
        span["end_t"] = self._clock()
        span["span_ms"] = (span["end_t"] - span["submit_t"]) * 1e3
        span["tokens_out"] = int(tokens_out)
        if error:
            span["error"] = error
        span["preemptions"] = sum(1 for e in span["events"]
                                  if e["kind"] == "preempt")
        self.finished_spans.append(span)
        if self.trace_path:
            with open(self.trace_path, "a") as f:
                f.write(json.dumps(span) + "\n")

    def class_summary(self) -> Dict[int, Dict[str, Any]]:
        """Per-priority-class aggregates over the finished spans: request
        counts by status, tokens out, and TTFT p50/p95 (ms, over spans
        that emitted a first token).  The scheduling smoke bench reads
        this for its per-class fairness/starvation record."""
        by_cls: Dict[int, Dict[str, Any]] = {}
        for span in self.finished_spans:
            cls = int(span.get("priority", 0))
            agg = by_cls.setdefault(cls, {"count": 0, "by_status": {},
                                          "tokens_out": 0, "_ttft": []})
            agg["count"] += 1
            st = span.get("status", "unknown")
            agg["by_status"][st] = agg["by_status"].get(st, 0) + 1
            agg["tokens_out"] += int(span.get("tokens_out", 0))
            if span.get("ttft_ms") is not None:
                agg["_ttft"].append(float(span["ttft_ms"]))
        for agg in by_cls.values():
            ttfts = sorted(agg.pop("_ttft"))
            if ttfts:
                agg["ttft_p50_ms"] = ttfts[len(ttfts) // 2]
                agg["ttft_p95_ms"] = ttfts[
                    min(len(ttfts) - 1, int(len(ttfts) * 0.95))]
            else:
                agg["ttft_p50_ms"] = agg["ttft_p95_ms"] = None
        return by_cls


def operator_costs(compiled) -> Dict[str, Any]:
    """Static operator-level attribution for one compiled XLA program:
    ``{"flops", "bytes", "by_class": {family: {flops, bytes, flop_share,
    byte_share}}}``.  Totals come from the version-portable
    :func:`repro.core.hlo_analysis.xla_cost_dict`; the per-family shares
    (gemm / ssm / norm / memory / arith / collective — the paper's
    operator taxonomy) from the trip-count-corrected HLO walk, which is
    what makes scanned-layer models attributable at all (XLA's aggregate
    counts a ``while`` body once regardless of trip count)."""
    from repro.core.hlo_analysis import analyze_hlo_text, xla_cost_dict
    xca = xla_cost_dict(compiled)
    out: Dict[str, Any] = {"flops": float(xca.get("flops", 0.0)),
                           "bytes": float(xca.get("bytes accessed", 0.0)),
                           "by_class": {}}
    try:
        summary = analyze_hlo_text(compiled.as_text())
    except Exception:                                   # pragma: no cover
        return out
    tf, tb = summary.flops, summary.bytes
    for clazz, c in sorted(summary.by_class().items()):
        out["by_class"][clazz] = {
            "flops": c["flops"], "bytes": c["bytes"],
            "flop_share": c["flops"] / tf if tf else 0.0,
            "byte_share": c["bytes"] / tb if tb else 0.0}
    return out


def read_trace(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL span trace written via ``REPRO_TRACE_PATH`` (one span
    object per line; blank lines ignored).  Raises ``ValueError`` when a
    line carries a different schema ``version`` — stale traces from an
    earlier (or later) layout must not be silently misread."""
    spans = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            span = json.loads(line)
            v = span.get("version")
            if v != TRACE_SCHEMA_VERSION:
                raise ValueError(
                    f"{path}:{i + 1}: trace span has schema version {v!r}, "
                    f"expected {TRACE_SCHEMA_VERSION} — stale trace file?")
            spans.append(span)
    return spans
