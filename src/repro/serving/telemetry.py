"""Structured serving telemetry: the per-(phase, KV-bucket) latency model,
per-request span traces, and static operator-level cost attribution.

The paper's core contribution is *operator-level* characterization —
selective-scan kernels account for >55% of edge-inference latency, and
the Transformer/SSM crossover only shows up when time is attributed per
phase and per sequence-length regime.  Collapsing the serving engine's
timing into two scalar EWMAs loses exactly that structure, and worse:
a first dispatch into a fresh KV bucket pays trace+compile, so an
unguarded sample poisons the steady-state estimate that deadline
admission and preemption-victim selection depend on — one bucket-ladder
climb could spuriously time out every queued request.

This module replaces the scalars with three layers:

* **Latency table** — one :class:`PhaseBucketStats` per
  ``(phase, kv_bucket)`` key (phases: ``prefill`` / ``decode``; bucket =
  the static KV rung the compiled program ran under, ``None`` for
  architectures without a KV cache).  Each entry keeps TWO
  :class:`LatencyRecord` s — ``steady`` and ``compile`` — so
  first-dispatch samples are *segregated*, never discarded: the compile
  record is observability (how much a ladder climb costs), the steady
  record is the only one feeding scheduling.  :meth:`Telemetry.estimate`
  answers "expected ms/token for this phase at this bucket" from the
  bucket's steady record, falling back to the phase-global steady record
  when the bucket has no samples yet.
* **Span traces** — per-request event timelines (queued -> prefill
  chunks -> decode bursts -> terminal state, with bucket, preemption,
  checkpoint, replay and fault events).  Consecutive same-phase
  same-bucket events coalesce (a 1000-burst decode is one event with
  ``bursts``/``tokens`` counters, split whenever the bucket climbs), so
  spans stay O(ladder rungs), not O(tokens).  When ``REPRO_TRACE_PATH``
  is set (or ``trace_path`` is passed), each finished span is appended
  to that file as one JSON line.
* **Operator attribution** — :func:`operator_costs` maps a compiled XLA
  program to flop/byte totals (via the version-portable
  :func:`repro.core.hlo_analysis.xla_cost_dict`) plus per-kernel-family
  shares (gemm / ssm / norm / memory / arith / collective) from the
  trip-count-corrected HLO walk — the paper's Table-style operator
  breakdown, derived statically so benchmarks can report it without a
  profiler.

All timestamps come from the injected ``clock`` (the engine passes its
own, so fault-injection tests with a fake clock see one consistent time
base across deadlines, latency samples and trace spans).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

# phases a latency key may carry (order = pipeline order)
PHASES = ("prefill", "decode")


@dataclass
class LatencyRecord:
    """EWMA + count + min/max over per-token latency samples (ms)."""

    ewma_ms: float = 0.0
    count: int = 0
    min_ms: float = float("inf")
    max_ms: float = 0.0

    def observe(self, ms: float, alpha: float) -> None:
        self.ewma_ms = ms if self.count == 0 \
            else alpha * ms + (1.0 - alpha) * self.ewma_ms
        self.count += 1
        self.min_ms = min(self.min_ms, ms)
        self.max_ms = max(self.max_ms, ms)

    def as_dict(self) -> Dict[str, Any]:
        return {"ewma_ms": self.ewma_ms, "count": self.count,
                "min_ms": None if self.count == 0 else self.min_ms,
                "max_ms": None if self.count == 0 else self.max_ms}


@dataclass
class PhaseBucketStats:
    """Latency for one (phase, kv_bucket) key: steady-state samples and
    first-dispatch (trace+compile) samples, segregated — only ``steady``
    ever feeds admission/preemption estimates."""

    steady: LatencyRecord = field(default_factory=LatencyRecord)
    compile: LatencyRecord = field(default_factory=LatencyRecord)

    def as_dict(self) -> Dict[str, Any]:
        return {"steady": self.steady.as_dict(),
                "compile": self.compile.as_dict()}


def _bucket_key(bucket: Optional[int]) -> int:
    # None (no KV cache / bucketing off) keys as -1 so the table stays
    # JSON-sortable; the phase-global aggregate lives under GLOBAL_KEY
    return -1 if bucket is None else int(bucket)


GLOBAL_KEY = "*"


class Telemetry:
    """Metrics + tracing hub for one :class:`ServingEngine` (or bench).

    ``clock`` is the time base (seconds); ``alpha`` the EWMA smoothing
    factor shared by every record; ``trace_path`` enables JSONL span
    export (defaults to the ``REPRO_TRACE_PATH`` env var, read once at
    construction).
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 alpha: float = 0.25,
                 trace_path: Optional[str] = None):
        import time
        self._clock = clock or time.monotonic
        self.alpha = float(alpha)
        self.trace_path = (trace_path if trace_path is not None
                           else os.environ.get("REPRO_TRACE_PATH") or None)
        # {(phase, bucket_key) -> PhaseBucketStats}; bucket GLOBAL_KEY is
        # the per-phase aggregate the estimate falls back to
        self._lat: Dict[Tuple[str, Any], PhaseBucketStats] = {}
        self._spans: Dict[int, Dict[str, Any]] = {}    # rid -> open span
        self.finished_spans: List[Dict[str, Any]] = []

    # ------------------------------------------------------- latency table
    def _entry(self, phase: str, key) -> PhaseBucketStats:
        if (phase, key) not in self._lat:
            self._lat[(phase, key)] = PhaseBucketStats()
        return self._lat[(phase, key)]

    def record_latency(self, phase: str, bucket: Optional[int],
                       tok_ms: float, *, compiled: bool = False) -> None:
        """One per-token latency sample for ``phase`` under ``bucket``.
        ``compiled=True`` marks a first-dispatch (trace+compile) sample:
        it lands in the segregated compile record and NEVER moves the
        steady-state estimate."""
        for key in (_bucket_key(bucket), GLOBAL_KEY):
            rec = self._entry(phase, key)
            (rec.compile if compiled else rec.steady).observe(
                tok_ms, self.alpha)

    def estimate(self, phase: str, bucket: Optional[int]) -> Optional[float]:
        """Steady-state ms/token for ``phase`` at ``bucket``; falls back
        to the phase-global steady record when the bucket is unmeasured;
        None when the phase has no steady samples at all."""
        for key in (_bucket_key(bucket), GLOBAL_KEY):
            rec = self._lat.get((phase, key))
            if rec is not None and rec.steady.count > 0:
                return rec.steady.ewma_ms
        return None

    def latency_snapshot(self) -> Dict[str, Dict[str, Any]]:
        """JSON-able view of the whole table:
        ``{"decode@256": {"steady": {...}, "compile": {...}}, ...}``
        (``@*`` = phase-global aggregate, ``@-1`` = unbucketed)."""
        return {f"{phase}@{key}": rec.as_dict()
                for (phase, key), rec in sorted(
                    self._lat.items(), key=lambda kv: (kv[0][0],
                                                       str(kv[0][1])))}

    # -------------------------------------------------------- span traces
    def begin_span(self, rid: int, *, prompt_len: int, max_new: int,
                   deadline_ms: Optional[float] = None,
                   t: Optional[float] = None) -> None:
        self._spans[rid] = {
            "rid": rid, "submit_t": self._clock() if t is None else t,
            "prompt_len": int(prompt_len), "max_new": int(max_new),
            "deadline_ms": deadline_ms, "status": "pending", "events": []}

    # repeated same-(kind, bucket) events merge into one counting event:
    # spans scale with bucket climbs and phase changes, not token counts
    _COALESCE = {"prefill": "chunks", "decode": "bursts",
                 "checkpoint": "count"}

    def event(self, rid: int, kind: str, *, bucket: Optional[int] = None,
              tokens: int = 0, **fields: Any) -> None:
        """Append one event to ``rid``'s span (no-op for unknown rids, so
        bench/test callers need no span bookkeeping).  ``prefill`` /
        ``decode`` / ``checkpoint`` events coalesce with the previous
        event when the kind AND bucket match."""
        span = self._spans.get(rid)
        if span is None:
            return
        ev: Dict[str, Any] = {"t": self._clock(), "kind": kind}
        if bucket is not None:
            ev["bucket"] = int(bucket)
        unit = self._COALESCE.get(kind)
        if unit is not None:
            prev = span["events"][-1] if span["events"] else None
            if (prev is not None and prev["kind"] == kind
                    and prev.get("bucket") == ev.get("bucket")):
                prev[unit] += 1
                if kind != "checkpoint":
                    prev["tokens"] += int(tokens)
                prev["t_last"] = ev["t"]
                return
            ev[unit] = 1
            if kind != "checkpoint":
                ev["tokens"] = int(tokens)
        ev.update(fields)
        span["events"].append(ev)

    def end_span(self, rid: int, status: str, *,
                 error: Optional[str] = None, tokens_out: int = 0) -> None:
        span = self._spans.pop(rid, None)
        if span is None:
            return
        span["status"] = status
        span["end_t"] = self._clock()
        span["span_ms"] = (span["end_t"] - span["submit_t"]) * 1e3
        span["tokens_out"] = int(tokens_out)
        if error:
            span["error"] = error
        span["preemptions"] = sum(1 for e in span["events"]
                                  if e["kind"] == "preempt")
        self.finished_spans.append(span)
        if self.trace_path:
            with open(self.trace_path, "a") as f:
                f.write(json.dumps(span) + "\n")


def operator_costs(compiled) -> Dict[str, Any]:
    """Static operator-level attribution for one compiled XLA program:
    ``{"flops", "bytes", "by_class": {family: {flops, bytes, flop_share,
    byte_share}}}``.  Totals come from the version-portable
    :func:`repro.core.hlo_analysis.xla_cost_dict`; the per-family shares
    (gemm / ssm / norm / memory / arith / collective — the paper's
    operator taxonomy) from the trip-count-corrected HLO walk, which is
    what makes scanned-layer models attributable at all (XLA's aggregate
    counts a ``while`` body once regardless of trip count)."""
    from repro.core.hlo_analysis import analyze_hlo_text, xla_cost_dict
    xca = xla_cost_dict(compiled)
    out: Dict[str, Any] = {"flops": float(xca.get("flops", 0.0)),
                           "bytes": float(xca.get("bytes accessed", 0.0)),
                           "by_class": {}}
    try:
        summary = analyze_hlo_text(compiled.as_text())
    except Exception:                                   # pragma: no cover
        return out
    tf, tb = summary.flops, summary.bytes
    for clazz, c in sorted(summary.by_class().items()):
        out["by_class"][clazz] = {
            "flops": c["flops"], "bytes": c["bytes"],
            "flop_share": c["flops"] / tf if tf else 0.0,
            "byte_share": c["bytes"] / tb if tb else 0.0}
    return out


def read_trace(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL span trace written via ``REPRO_TRACE_PATH`` (one span
    object per line; blank lines ignored)."""
    spans = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                spans.append(json.loads(line))
    return spans
