"""Durable checkpoint store: crash-surviving persistence for the serving
engine's crc-tagged checkpoint/preemption blobs plus request metadata.

The in-engine fault-tolerance layer (divergence sentinels, checkpoint
replay, blob integrity) keeps a *process* healthy; this module makes the
checkpoints survive the process.  A :class:`CheckpointStore` owns one
directory (``REPRO_CHECKPOINT_DIR`` or an explicit path)::

    <root>/manifest.json          # atomic write-rename, schema-versioned
    <root>/blobs/r<rid>-<seq>.blob

Every mutation is **atomic at the file level** (write to a ``.tmp``
sibling, fsync, ``os.replace``), and the manifest is the single commit
point: blob files are staged first, the manifest that references them is
replaced second, and files no manifest entry references are pruned after
the next commit.  A crash between the two leaves the previous manifest
intact and the staged file as ignorable garbage — never a half-written
record in the recovery path.

Blob container format (``dump_blob`` / ``parse_blob``): a magic prefix,
an 8-byte little-endian header length, a JSON header declaring every
array's shape/dtype/offset plus the blob's existing ``__meta__``
integrity record verbatim, then the concatenated raw array bytes.  A
torn (truncated) or bit-damaged file fails parsing or the per-key crc32
in :func:`repro.serving.cache.validate_blob` with
:class:`~repro.serving.faults.CacheCorruption` — the engine's rehydration
path degrades such a request to replay-from-prompt, never a crash.

The manifest carries a **layout fingerprint** (config name + ``max_seq``
+ the slot blob schema): an engine built with a different config or
cache geometry refuses to rehydrate the store rather than scattering
mis-shaped rows.  Retention is bounded: only the newest
``REPRO_CHECKPOINT_RETAIN`` blob files per request stay referenced.

This module never reads a wall clock (``scripts/check_clock.py`` lints
the serving layer): all timestamps in the manifest come from the
engine's injectable clock, passed in as plain record fields.
"""
from __future__ import annotations

import json
import logging
import os
import re
import zlib
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serving.cache import BLOB_META_KEY
from repro.serving.faults import CacheCorruption

log = logging.getLogger("repro.serving.store")

#: Manifest / blob-container schema version; a mismatch cold-starts the
#: store (with a logged warning) instead of guessing at old layouts.
STORE_VERSION = 1

BLOB_MAGIC = b"RPROBLOB1\n"
MANIFEST_NAME = "manifest.json"
BLOB_DIR = "blobs"

_BLOB_FILE_RE = re.compile(r"^r-?\d+-(\d+)\.blob$")


def layout_fingerprint(cfg_name: str, max_seq: int,
                       schema: Dict[str, Any]) -> str:
    """crc32 fingerprint of (config, cache geometry, slot blob schema).
    Two engines share a store only when this matches — same leaf keys,
    shapes and dtypes, so every persisted blob fits the new cache."""
    blob = json.dumps([cfg_name, int(max_seq), schema], sort_keys=True)
    return f"{zlib.crc32(blob.encode()):08x}"


def dump_blob(blob: Dict[str, Any]) -> bytes:
    """Serialize an offload blob (numpy arrays + the ``__meta__`` JSON
    string) to one self-describing byte string.  Key order is sorted, so
    identical blobs serialize identically."""
    payload = bytearray()
    arrays: Dict[str, Any] = {}
    for k in sorted(k for k in blob if k != BLOB_META_KEY):
        a = np.ascontiguousarray(blob[k])
        arrays[k] = {"shape": list(a.shape), "dtype": str(a.dtype),
                     "offset": len(payload), "nbytes": int(a.nbytes)}
        payload += a.tobytes()
    header = json.dumps({"version": STORE_VERSION, "arrays": arrays,
                         "meta": blob.get(BLOB_META_KEY)},
                        sort_keys=True).encode()
    return (BLOB_MAGIC + len(header).to_bytes(8, "little")
            + header + bytes(payload))


def parse_blob(data: bytes) -> Dict[str, Any]:
    """Inverse of :func:`dump_blob`.  Raises :class:`CacheCorruption` on
    ANY malformation — bad magic, torn header, payload shorter than the
    header declares — so a truncated file can never round-trip into a
    silently shorter cache row."""
    if data[:len(BLOB_MAGIC)] != BLOB_MAGIC:
        raise CacheCorruption("durable blob: bad magic (torn or foreign "
                              "file)")
    off = len(BLOB_MAGIC)
    if len(data) < off + 8:
        raise CacheCorruption("durable blob: truncated before header "
                              "length")
    hlen = int.from_bytes(data[off:off + 8], "little")
    off += 8
    if len(data) < off + hlen:
        raise CacheCorruption("durable blob: truncated inside header")
    try:
        header = json.loads(data[off:off + hlen])
        arrays = header["arrays"]
        version = header["version"]
    except (ValueError, KeyError, TypeError) as e:
        raise CacheCorruption(
            f"durable blob: unreadable header ({e})") from None
    if version != STORE_VERSION:
        raise CacheCorruption(
            f"durable blob: container version {version} != {STORE_VERSION}")
    payload = data[off + hlen:]
    out: Dict[str, Any] = {}
    for k, decl in arrays.items():
        try:
            shape = tuple(int(s) for s in decl["shape"])
            dtype = np.dtype(decl["dtype"])
            start, nbytes = int(decl["offset"]), int(decl["nbytes"])
        except (KeyError, TypeError, ValueError) as e:
            raise CacheCorruption(
                f"durable blob: bad array declaration ({e})",
                key=k) from None
        if start < 0 or start + nbytes > len(payload):
            raise CacheCorruption(
                f"durable blob: payload truncated ({start + nbytes} bytes "
                f"declared, {len(payload)} present)", key=k)
        a = np.frombuffer(payload, dtype=dtype,
                          count=nbytes // max(dtype.itemsize, 1),
                          offset=start)
        try:
            out[k] = a.reshape(shape)
        except ValueError as e:
            raise CacheCorruption(
                f"durable blob: shape/size mismatch ({e})", key=k) from None
    meta = header.get("meta")
    if meta is not None:
        out[BLOB_META_KEY] = meta
    return out


class CheckpointStore:
    """Versioned on-disk store for one engine's durable state.

    The in-memory ``manifest`` mirrors the last committed state plus
    uncommitted mutations; :meth:`commit` atomically replaces
    ``manifest.json`` and then prunes unreferenced blob files.  Request
    records are plain dicts (see ``ServingEngine._persist_request`` for
    the fields); blobs are referenced by store-relative path,
    newest-first, bounded to ``retain`` entries per request."""

    def __init__(self, root: str, retain: Optional[int] = None):
        self.root = root
        self.blob_dir = os.path.join(root, BLOB_DIR)
        os.makedirs(self.blob_dir, exist_ok=True)
        if retain is None:
            retain = int(os.environ.get("REPRO_CHECKPOINT_RETAIN", "2") or 2)
        self.retain = max(1, int(retain))
        self.manifest = self._load_manifest()
        self._dirty = False
        # monotonic blob sequence across restarts: a restarted engine must
        # never overwrite a predecessor's still-referenced blob file
        seqs = [int(m.group(1)) for f in os.listdir(self.blob_dir)
                for m in [_BLOB_FILE_RE.match(f)] if m]
        self._seq = max(seqs, default=-1) + 1

    @classmethod
    def from_env(cls) -> Optional["CheckpointStore"]:
        root = os.environ.get("REPRO_CHECKPOINT_DIR", "")
        return cls(root) if root else None

    # ------------------------------------------------------------- manifest
    def _load_manifest(self) -> Dict[str, Any]:
        empty = {"version": STORE_VERSION, "fingerprint": None,
                 "requests": {}}
        path = os.path.join(self.root, MANIFEST_NAME)
        if not os.path.exists(path):
            return empty
        try:
            with open(path) as f:
                man = json.load(f)
            if man.get("version") != STORE_VERSION:
                log.warning("checkpoint store %s: manifest version %r != "
                            "%d; starting cold", self.root,
                            man.get("version"), STORE_VERSION)
                return empty
            man.setdefault("fingerprint", None)
            man.setdefault("requests", {})
            return man
        except (ValueError, OSError) as e:
            # a torn manifest means the LAST commit never landed; there is
            # nothing consistent to recover, so cold-start (never crash)
            log.warning("checkpoint store %s: unreadable manifest (%s); "
                        "starting cold", self.root, e)
            return empty

    @property
    def requests(self) -> Dict[str, Dict[str, Any]]:
        return self.manifest["requests"]

    def set_fingerprint(self, fp: str) -> None:
        if self.manifest.get("fingerprint") != fp:
            self.manifest["fingerprint"] = fp
            self._dirty = True

    def record(self, rid: int, **fields: Any) -> Dict[str, Any]:
        """Merge ``fields`` into request ``rid``'s manifest record
        (uncommitted until :meth:`commit`)."""
        rec = self.requests.setdefault(str(rid), {"rid": int(rid),
                                                  "blobs": []})
        rec.update(fields)
        self._dirty = True
        return rec

    def forget(self, rid: int) -> None:
        """Drop request ``rid``'s record; its blob files become prunable
        at the next commit."""
        if self.requests.pop(str(rid), None) is not None:
            self._dirty = True

    def commit(self) -> None:
        """Atomically replace the on-disk manifest with the in-memory
        state, then prune blob files nothing references.  No-op when
        nothing changed since the last commit."""
        if not self._dirty:
            return
        self._atomic_write(os.path.join(self.root, MANIFEST_NAME),
                           json.dumps(self.manifest).encode())
        self._dirty = False
        self._prune()

    # ---------------------------------------------------------------- blobs
    def stage_blob(self, rid: int, blob: Dict[str, Any]) -> str:
        """Write ``blob`` to a fresh file and reference it newest-first in
        ``rid``'s record (trimmed to ``retain``).  The record change only
        becomes recoverable at the next :meth:`commit` — the stage/commit
        split is what makes a crash between them harmless."""
        rel = f"{BLOB_DIR}/r{int(rid)}-{self._seq:08d}.blob"
        self._seq += 1
        self._atomic_write(os.path.join(self.root, rel), dump_blob(blob))
        rec = self.record(rid)
        rec["blobs"] = ([rel] + list(rec.get("blobs") or []))[:self.retain]
        return rel

    def load_blob(self, rel: str) -> Dict[str, Any]:
        """Read + parse one referenced blob file.  Raises
        :class:`CacheCorruption` when the file is missing, unreadable or
        torn — callers degrade to replay-from-prompt (older blobs are
        retained for forensics only; the manifest's resume metadata
        matches the newest blob alone)."""
        try:
            with open(os.path.join(self.root, rel), "rb") as f:
                data = f.read()
        except OSError as e:
            raise CacheCorruption(
                f"durable blob {rel!r} unreadable: {e}") from None
        return parse_blob(data)

    def _prune(self) -> None:
        referenced = {os.path.basename(rel)
                      for rec in self.requests.values()
                      for rel in rec.get("blobs") or []}
        for fn in os.listdir(self.blob_dir):
            if fn.endswith(".blob") and fn not in referenced:
                try:
                    os.remove(os.path.join(self.blob_dir, fn))
                except OSError:
                    pass

    @staticmethod
    def _atomic_write(path: str, data: bytes) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    # ---------------------------------------------------------- inspection
    def rids(self) -> List[int]:
        return sorted(rec["rid"] for rec in self.requests.values())
