"""Deterministic fault injection for the serving engine.

Every recovery path in the fault-tolerance layer (divergence sentinels +
checkpoint replay, blob-integrity validation, the no-progress watchdog)
is exercised reproducibly in CI by flipping faults at *exact* points: a
seeded, env-driven plan says which engine iteration poisons which slot,
which request's offload blob gets bit-flipped, and when prefill progress
freezes.  Nothing here is probabilistic at run time — the only RNG is a
``numpy`` generator seeded from ``REPRO_FAULT_SEED`` used to pick the
flipped bit, so the same spec + seed corrupts the same byte every run.

Spec grammar (``REPRO_FAULT_SPEC``)::

    spec    := clause ("," clause)*
    clause  := kind ["@" param (":" param)*]
    param   := key "=" value          # value: int, or rNN for rid keys

    nan_decode@iter=I[:slot=S][:n=N]   poison slot S's cache with NaN
                                       right before the decode burst of
                                       engine iteration >= I (N times;
                                       n=-1 -> every iteration from I on)
    nan_prefill@chunk=C[:row=R][:n=N]  poison row R of the in-flight
                                       prefill group's cache before its
                                       group-local chunk C runs
    corrupt_blob@rid=R[:n=N]           flip one bit in request R's next
                                       offload blob (preemption or
                                       checkpoint)
    stall@iter=I[:n=N]                 freeze prefill progress starting
                                       at engine iteration I (for N
                                       iterations; default forever —
                                       the watchdog's trip condition)
    kill@iter=I[:point=P][:n=N]        raise :class:`SimulatedCrash` at
                                       engine iteration >= I — a process
                                       death the durable checkpoint
                                       store must survive.  point=0
                                       (default) kills between
                                       iterations (before any state
                                       mutates); point=1 kills inside
                                       ``_checkpoint``, after blob files
                                       are staged but before the
                                       manifest commit lands

Example::

    REPRO_FAULT_SPEC="nan_decode@iter=7:slot=2,corrupt_blob@rid=r3,stall@iter=12"

The engine consumes a :class:`FaultPlan` (``FaultPlan.from_env()`` by
default, or passed explicitly for in-process tests/benches); an empty
plan short-circuits every hook, so the healthy path pays a single ``if``.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

KINDS = ("nan_decode", "nan_prefill", "corrupt_blob", "stall", "kill")

_DEFAULTS = {
    "nan_decode": {"slot": 0, "n": 1},
    "nan_prefill": {"row": 0, "n": 1},
    "corrupt_blob": {"n": 1},
    "stall": {"n": -1},
    "kill": {"point": 0, "n": 1},
}
_REQUIRED = {"nan_decode": ("iter",), "nan_prefill": ("chunk",),
             "corrupt_blob": ("rid",), "stall": ("iter",),
             "kill": ("iter",)}


class SimulatedCrash(RuntimeError):
    """A deterministic process death injected by a ``kill`` clause.

    Deliberately NOT a :class:`repro.serving.faults.RequestError`: it
    models the whole engine dying, not one request failing, so it
    escapes ``ServingEngine.run`` instead of being quarantined — exactly
    like a real SIGKILL would.  Restart-recovery tests construct a fresh
    engine over the same :class:`~repro.serving.store.CheckpointStore`
    and assert the resumed stream is bit-identical."""


@dataclass
class FaultClause:
    kind: str
    params: Dict[str, int]
    fired: int = 0

    @property
    def budget(self) -> int:
        return int(self.params["n"])

    def _spend(self) -> bool:
        if self.budget >= 0 and self.fired >= self.budget:
            return False
        self.fired += 1
        return True


def _parse_value(key: str, val: str) -> int:
    if key == "rid" and val[:1] == "r":
        val = val[1:]
    try:
        return int(val)
    except ValueError:
        raise ValueError(f"fault spec: non-integer value {val!r} for "
                         f"{key!r}") from None


def parse_spec(spec: str) -> List[FaultClause]:
    clauses = []
    for raw in filter(None, (c.strip() for c in spec.split(","))):
        kind, _, rest = raw.partition("@")
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(f"fault spec: unknown kind {kind!r} in {raw!r} "
                             f"(known: {', '.join(KINDS)})")
        params = dict(_DEFAULTS[kind])
        for p in filter(None, rest.split(":")):
            key, eq, val = p.partition("=")
            if not eq:
                raise ValueError(f"fault spec: malformed param {p!r} in "
                                 f"{raw!r} (want key=value)")
            params[key.strip()] = _parse_value(key.strip(), val.strip())
        for req in _REQUIRED[kind]:
            if req not in params:
                raise ValueError(f"fault spec: {kind!r} clause needs "
                                 f"{req}=... ({raw!r})")
        clauses.append(FaultClause(kind, params))
    return clauses


@dataclass
class FaultPlan:
    """A parsed, stateful injection plan.  Each clause tracks how many
    times it has fired; a budget of ``n=-1`` never exhausts."""

    clauses: List[FaultClause] = field(default_factory=list)
    seed: int = 0

    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultPlan":
        return cls(parse_spec(spec), seed)

    @classmethod
    def from_env(cls) -> "FaultPlan":
        spec = os.environ.get("REPRO_FAULT_SPEC", "")
        seed = int(os.environ.get("REPRO_FAULT_SEED", "0") or 0)
        return cls.from_spec(spec, seed) if spec else cls([], seed)

    @property
    def active(self) -> bool:
        return bool(self.clauses)

    # ------------------------------------------------------------ hooks
    def nan_decode_slots(self, it: int) -> List[int]:
        """Slots to poison before iteration ``it``'s decode burst."""
        out = []
        for c in self.clauses:
            if (c.kind == "nan_decode" and it >= c.params["iter"]
                    and c._spend()):
                out.append(int(c.params["slot"]))
        return out

    def nan_prefill_rows(self, chunk_idx: int) -> List[int]:
        """Group rows to poison before group-local chunk ``chunk_idx``."""
        out = []
        for c in self.clauses:
            if (c.kind == "nan_prefill" and chunk_idx >= c.params["chunk"]
                    and c._spend()):
                out.append(int(c.params["row"]))
        return out

    def stalled(self, it: int) -> bool:
        """True when prefill progress is frozen at iteration ``it``."""
        for c in self.clauses:
            if c.kind != "stall":
                continue
            start, n = c.params["iter"], c.params["n"]
            if it >= start and (n < 0 or it < start + n):
                return True
        return False

    def kill_now(self, it: int, point: int = 0) -> bool:
        """True when a ``kill`` clause for crash-point ``point`` fires at
        engine iteration ``it`` — the engine raises
        :class:`SimulatedCrash` at that exact spot."""
        for c in self.clauses:
            if (c.kind == "kill" and c.params["point"] == point
                    and it >= c.params["iter"] and c._spend()):
                return True
        return False

    def corrupt_blob(self, rid: int,
                     blob: Dict[str, Any]) -> Dict[str, Any]:
        """Bit-flip one payload byte of ``blob`` if a clause targets
        ``rid``; returns the (possibly copied+damaged) blob."""
        hit = False
        for c in self.clauses:
            if (c.kind == "corrupt_blob" and c.params["rid"] == rid
                    and c._spend()):
                hit = True
        if not hit:
            return blob
        keys = sorted(k for k, v in blob.items()
                      if isinstance(v, np.ndarray) and v.nbytes > 0)
        if not keys:
            return blob
        rng = np.random.default_rng((self.seed, rid & 0x7FFFFFFF))
        key = keys[int(rng.integers(len(keys)))]
        arr = np.array(blob[key])              # private copy
        # flip inside the checksummed region: KV leaves carry a
        # live-prefix-bounded crc (dead tail rows are zeros, masked on
        # read, and excluded from validation — a flip there would model
        # corruption that cannot affect any output)
        live = {}
        try:
            live = json.loads(blob.get("__meta__", "{}")).get("live", {})
        except (TypeError, ValueError):
            pass
        rows = live.get(key)
        region = arr if rows is None else arr[:, :, :int(rows)]
        if region.nbytes == 0:
            rows, region = None, arr
        flat = np.ascontiguousarray(region).view(np.uint8).reshape(-1)
        byte = int(rng.integers(flat.size))
        flat[byte] ^= np.uint8(1 << int(rng.integers(8)))
        if rows is None:
            arr = flat.view(arr.dtype).reshape(arr.shape)
        else:
            arr[:, :, :int(rows)] = flat.view(arr.dtype).reshape(
                region.shape)
        out = dict(blob)
        out[key] = arr
        return out


def poison_slot(cache: Any, b: int) -> Any:
    """Overwrite every float cache leaf's slot ``b`` with NaN (segment
    leaves are stacked ``[n_rep, B, ...]``; ``pos`` and other integer
    leaves are untouched).  Models NaN contamination of one request's
    KV/conv/SSM state: the next forward produces non-finite activations
    for that row only, which is exactly what the divergence sentinel must
    catch without disturbing co-batched rows."""
    def f(leaf):
        if (leaf.ndim >= 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return leaf.at[:, b].set(jnp.nan)
        return leaf
    segs = [jax.tree_util.tree_map(f, seg) for seg in cache["segments"]]
    return {"segments": segs, "pos": cache["pos"]}
