"""Static KV bucketing: bound attention reads to the live prefix.

The paper's operator breakdown shows attention over the KV window dominating
Transformer/hybrid latency as context grows.  Without bucketing, every
chunked-prefill step and decode burst attends the *entire* ``max_seq`` cache
under a mask, so a 512-token chunk at offset 1K pays the same attention
FLOPs/IO as one at offset 56K — exactly the scaling curve the paper measures
is flattened into a constant.

The fix is a host-side *bucket ladder*: before dispatching a compiled chunk
or decode program, the caller picks the smallest power-of-two KV extent that
covers the live prefix (``max(pos) + chunk``) and passes it as a static
argument.  The models layer slices the KV caches to that extent, runs the
flash/decode kernels over the slice, and writes the slice back — masked
attention over the dropped tail contributes exact zeros, so outputs are
bit-identical to the full-cache program while FLOPs/IO track the true
prefix.  Because the ladder has O(log2(extent)) rungs, XLA compiles a
bounded number of programs no matter how positions evolve.

Ladder top = the model's largest KV-cache extent, not the serving
``max_seq``: append-only caches span ``max_seq``, but rolling
sliding-window (ring-buffer) caches span exactly their ``window`` — for a
pure-windowed architecture the ladder therefore caps at ``window`` and
compiles stay O(log window) however long the prompt grows
(:func:`kv_cache_extent` computes the cap from the config).  Capping
``needed`` at the extent is also what makes bucket-slicing a ring safe: a
ring leaf is only sliced when ``bucket < window``, and since
``bucket >= min(needed, extent)`` with ``window <= extent`` that implies
``bucket >= max(pos) + chunk`` — i.e. the ring has not wrapped inside the
slice.

Edge discipline (the classic off-by-one): a prefix that lands exactly on a
rung (``pos + chunk == bucket``) selects *that* rung — never the next one
(a spurious recompile) and never the previous one (the newest KV row would
fall off the slice and decode would read a stale row).
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.config import ModelConfig

# Smallest rung: below this, slicing saves nothing but still costs a compile.
MIN_BUCKET = 128


def bucket_ladder(max_seq: int, min_bucket: int = MIN_BUCKET) -> Tuple[int, ...]:
    """Power-of-two rungs ``min_bucket, 2*min_bucket, ... < max_seq`` plus
    ``max_seq`` itself as the top rung (so the full cache is always a valid
    selection and admission control keeps its ``max_seq`` contract)."""
    if max_seq <= 0:
        raise ValueError(f"max_seq must be positive, got {max_seq}")
    rungs = []
    b = min_bucket
    while b < max_seq:
        rungs.append(b)
        b *= 2
    rungs.append(max_seq)
    return tuple(rungs)


def select_kv_bucket(needed: int, max_seq: int,
                     min_bucket: int = MIN_BUCKET) -> int:
    """Smallest rung >= ``needed`` (the live prefix extent the next program
    will read *and* write: ``max(pos) + chunk``).

    ``needed == rung`` returns exactly that rung; ``needed`` may not exceed
    ``max_seq`` — callers cap it at the ladder top first (the model's KV
    extent from :func:`kv_cache_extent`; admission control rejects prompts
    beyond the serving ``max_seq`` earlier)."""
    if needed > max_seq:
        raise ValueError(
            f"needed KV extent {needed} exceeds max_seq {max_seq}")
    for b in bucket_ladder(max_seq, min_bucket):
        if b >= needed:
            return b
    return max_seq  # pragma: no cover — ladder always ends at max_seq


def clamped_bucket(needed: int, extent: Optional[int],
                   min_bucket: int = MIN_BUCKET) -> Optional[int]:
    """The rung a program covering ``needed`` KV rows will run under, with
    ``needed`` capped at the ladder top ``extent`` (the model's largest
    KV-cache extent from :func:`kv_cache_extent`).  ``None`` extent means
    the model holds no KV cache — no bucketing, returns ``None``.  One
    rule for every caller — the engine's decode bursts, the prefill
    scheduler's chunks, and the telemetry layer's admission estimates —
    so the latency model is keyed by exactly the buckets the compiled
    programs actually run under."""
    if extent is None:
        return None
    return select_kv_bucket(min(max(needed, 1), extent), extent, min_bucket)


def kv_cache_extent(cfg: ModelConfig, max_seq: int) -> Optional[int]:
    """Largest KV-cache leaf extent the model allocates at ``max_seq`` —
    the bucket-ladder top.  Append-only caches (dense/moe/hybrid/shared
    attention) span ``max_seq``; rolling "local" caches span exactly their
    sliding window (which may exceed ``max_seq`` — the rolling invariant
    needs all ``window`` slots).  None when no layer holds a KV cache
    (pure-SSM stacks: bucketing would cost a compile per rung for
    nothing)."""
    kinds = set(cfg.layer_kinds)
    extents = []
    if kinds & {"dense", "moe", "dense_moe", "hybrid_par"}:
        extents.append(max_seq)
    if cfg.shared_attn is not None and "mamba2+shared" in kinds:
        extents.append(max_seq)
    if "local" in kinds:
        window = cfg.attn.sliding_window if cfg.attn is not None else None
        extents.append(window if window is not None else max_seq)
    return max(extents) if extents else None


def rope_len_for(cfg: ModelConfig, max_seq: int) -> Optional[int]:
    """Static rope-table override for chunk/decode programs: needed exactly
    when the model's largest KV cache (the window, for rolling archs) is
    smaller than the positions the serving layer will visit.  One rule for
    the engine, the prefill scheduler, and the benches — rope table size
    never changes the values at a given position, only coverage."""
    extent = kv_cache_extent(cfg, max_seq)
    return max_seq if extent is not None and extent < max_seq else None
