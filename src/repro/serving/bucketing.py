"""Static KV bucketing: bound attention reads to the live prefix.

The paper's operator breakdown shows attention over the KV window dominating
Transformer/hybrid latency as context grows.  Without bucketing, every
chunked-prefill step and decode burst attends the *entire* ``max_seq`` cache
under a mask, so a 512-token chunk at offset 1K pays the same attention
FLOPs/IO as one at offset 56K — exactly the scaling curve the paper measures
is flattened into a constant.

The fix is a host-side *bucket ladder*: before dispatching a compiled chunk
or decode program, the caller picks the smallest power-of-two KV extent that
covers the live prefix (``max(pos) + chunk``) and passes it as a static
argument.  The models layer slices the KV cache to that extent, runs the
flash/decode kernels over the slice, and writes the slice back — masked
attention over the dropped tail contributes exact zeros, so outputs are
bit-identical to the full-cache program while FLOPs/IO track the true
prefix.  Because the ladder has O(log2(max_seq)) rungs, XLA compiles a
bounded number of programs no matter how positions evolve.

Edge discipline (the classic off-by-one): a prefix that lands exactly on a
rung (``pos + chunk == bucket``) selects *that* rung — never the next one
(a spurious recompile) and never the previous one (the newest KV row would
fall off the slice and decode would read a stale row).
"""
from __future__ import annotations

from typing import Tuple

# Smallest rung: below this, slicing saves nothing but still costs a compile.
MIN_BUCKET = 128


def bucket_ladder(max_seq: int, min_bucket: int = MIN_BUCKET) -> Tuple[int, ...]:
    """Power-of-two rungs ``min_bucket, 2*min_bucket, ... < max_seq`` plus
    ``max_seq`` itself as the top rung (so the full cache is always a valid
    selection and admission control keeps its ``max_seq`` contract)."""
    if max_seq <= 0:
        raise ValueError(f"max_seq must be positive, got {max_seq}")
    rungs = []
    b = min_bucket
    while b < max_seq:
        rungs.append(b)
        b *= 2
    rungs.append(max_seq)
    return tuple(rungs)


def select_kv_bucket(needed: int, max_seq: int,
                     min_bucket: int = MIN_BUCKET) -> int:
    """Smallest rung >= ``needed`` (the live prefix extent the next program
    will read *and* write: ``max(pos) + chunk``).

    ``needed == rung`` returns exactly that rung; ``needed`` may not exceed
    ``max_seq`` (admission control rejects such prompts earlier)."""
    if needed > max_seq:
        raise ValueError(
            f"needed KV extent {needed} exceeds max_seq {max_seq}")
    for b in bucket_ladder(max_seq, min_bucket):
        if b >= needed:
            return b
    return max_seq  # pragma: no cover — ladder always ends at max_seq
