"""Typed serving metrics registry: counters, gauges and histograms with
JSONL and Prometheus text exports.

The serving engine's ``stats`` dict grew one ad-hoc scalar per PR; none of
it was typed, labelled, or exportable to the monitoring stack a real
deployment scrapes.  This module is the structured replacement: a small
registry of named instruments —

* :class:`Counter` — monotone totals (requests admitted, tokens decoded,
  checkpoint bytes, bucket-ladder climbs).  ``inc`` of a negative amount
  is a caller bug and raises.
* :class:`Gauge` — point-in-time values (queue depth, live slots,
  tokens/s per phase).
* :class:`Histogram` — distribution of samples over fixed bucket bounds
  (decode-burst / prefill-chunk wall ms), exported cumulatively the way
  Prometheus expects.

Every instrument supports Prometheus-style labels via :meth:`labels`
(children are cached per label-set, so hot-path calls are one dict
lookup).  The registry snapshots to a JSON-able dict (pure copy — two
consecutive snapshots are equal and mutating one never touches the
registry), exports one JSON line per call via :meth:`MetricsRegistry.export`
(``REPRO_METRICS_PATH``; a ``.prom`` suffix switches to the Prometheus
text exposition format, full escaping included), and is shared by
``ServingEngine``, ``ChunkedPrefill`` and the cache offload/restore path
through plain get-or-create lookups — no global mutable default registry.
"""
from __future__ import annotations

import copy
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

#: schema version stamped on every metrics JSONL line (bump on breaking
#: changes so downstream readers can reject stale files)
METRICS_SCHEMA_VERSION = 1

#: default histogram bounds (ms-scale latencies); +Inf is implicit
DEFAULT_BUCKETS = (0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0, 2500.0)

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label(v: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (str(v).replace("\\", "\\\\").replace("\"", "\\\"")
            .replace("\n", "\\n"))


def _escape_help(v: str) -> str:
    """HELP-line escaping: backslash and newline only (quotes are legal)."""
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _fmt_labels(labels: _LabelKey, extra: Optional[Tuple[str, str]] = None
                ) -> str:
    pairs = list(labels) + ([extra] if extra else [])
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{_escape_label(v)}"' for k, v in pairs) + "}"


class _Child:
    """One (instrument, label-set) time series."""

    def __init__(self, labels: _LabelKey):
        self.label_pairs = labels


class _CounterChild(_Child):
    def __init__(self, labels: _LabelKey):
        super().__init__(labels)
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount


class _GaugeChild(_Child):
    def __init__(self, labels: _LabelKey):
        super().__init__(labels)
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class _HistogramChild(_Child):
    def __init__(self, labels: _LabelKey, bounds: Tuple[float, ...]):
        super().__init__(labels)
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)    # last = > bounds[-1]
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> List[Tuple[str, int]]:
        """Prometheus-style cumulative (le, count) rows ending at +Inf."""
        out = []
        run = 0
        for b, c in zip(self.bounds, self.counts):
            run += c
            out.append((repr(float(b)), run))
        out.append(("+Inf", self.count))
        return out

    def quantile(self, q: float) -> Optional[float]:
        """Prometheus-style estimated q-quantile (linear interpolation
        inside the covering bucket; the overflow bucket reports its lower
        bound — an honest floor, since nothing bounds it above).  None
        until a sample has been observed."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return None
        rank = q * self.count
        run = 0
        lo = 0.0
        for b, c in zip(self.bounds, self.counts):
            if run + c >= rank and c > 0:
                return lo + (b - lo) * max(rank - run, 0.0) / c
            run += c
            lo = b
        return self.bounds[-1]


class _Instrument:
    """A named metric family: the no-label default child plus any
    labelled children created through :meth:`labels`."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", **kwargs: Any):
        self.name = name
        self.help = help
        self._kwargs = kwargs
        self._children: Dict[_LabelKey, _Child] = {}

    def _child_cls(self):
        raise NotImplementedError

    def labels(self, **labels: str):
        key = _label_key(labels)
        child = self._children.get(key)
        if child is None:
            child = self._child_cls()(key, **self._kwargs)
            self._children[key] = child
        return child

    @property
    def _default(self):
        return self.labels()

    def children(self) -> List[_Child]:
        return list(self._children.values())


class Counter(_Instrument):
    kind = "counter"

    def _child_cls(self):
        return _CounterChild

    def inc(self, amount: float = 1.0) -> None:
        self._default.inc(amount)

    @property
    def value(self) -> float:
        return self._default.value


class Gauge(_Instrument):
    kind = "gauge"

    def _child_cls(self):
        return _GaugeChild

    def set(self, value: float) -> None:
        self._default.set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default.inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default.dec(amount)

    @property
    def value(self) -> float:
        return self._default.value


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError(f"{name}: histogram needs >= 1 bucket bound")
        super().__init__(name, help, bounds=bounds)
        self.bounds = bounds

    def _child_cls(self):
        return _HistogramChild

    def observe(self, value: float) -> None:
        self._default.observe(value)

    def quantile(self, q: float) -> Optional[float]:
        return self._default.quantile(q)


class MetricsRegistry:
    """Get-or-create instrument registry for one serving process.

    ``clock`` stamps exported JSONL lines (injectable so fake-clock tests
    see deterministic timestamps); ``path`` is the default export target,
    falling back to the ``REPRO_METRICS_PATH`` environment variable (read
    once at construction).
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 path: Optional[str] = None):
        self._clock = clock or time.monotonic
        self.default_path = (path if path is not None
                             else os.environ.get("REPRO_METRICS_PATH") or None)
        self._metrics: Dict[str, _Instrument] = {}

    def _get(self, cls, name: str, help: str, **kwargs: Any):
        inst = self._metrics.get(name)
        if inst is None:
            inst = cls(name, help, **kwargs)
            self._metrics[name] = inst
        elif not isinstance(inst, cls):
            raise ValueError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"requested {cls.kind}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    # --------------------------------------------------------------- export
    def snapshot(self) -> Dict[str, Any]:
        """Pure JSON-able copy of every time series.  Idempotent: calling
        it twice without intervening updates yields equal dicts, and the
        returned structure shares no state with the registry."""
        out: Dict[str, Any] = {"version": METRICS_SCHEMA_VERSION,
                               "metrics": {}}
        for name in sorted(self._metrics):
            inst = self._metrics[name]
            samples = []
            for child in inst.children():
                labels = dict(child.label_pairs)
                if isinstance(child, _HistogramChild):
                    samples.append({
                        "labels": labels, "sum": child.sum,
                        "count": child.count,
                        "buckets": [[le, c] for le, c in child.cumulative()]})
                else:
                    samples.append({"labels": labels, "value": child.value})
            out["metrics"][name] = {"type": inst.kind, "help": inst.help,
                                    "samples": samples}
        return copy.deepcopy(out)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (escaped HELP lines and label
        values, cumulative histogram buckets with the +Inf rail)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            inst = self._metrics[name]
            if inst.help:
                lines.append(f"# HELP {name} {_escape_help(inst.help)}")
            lines.append(f"# TYPE {name} {inst.kind}")
            for child in inst.children():
                lp = child.label_pairs
                if isinstance(child, _HistogramChild):
                    for le, c in child.cumulative():
                        lines.append(
                            f"{name}_bucket{_fmt_labels(lp, ('le', le))} {c}")
                    lines.append(f"{name}_sum{_fmt_labels(lp)} {child.sum}")
                    lines.append(f"{name}_count{_fmt_labels(lp)} "
                                 f"{child.count}")
                else:
                    lines.append(f"{name}{_fmt_labels(lp)} {child.value}")
        return "\n".join(lines) + "\n"

    def export(self, path: Optional[str] = None) -> Optional[str]:
        """Write the current state to ``path`` (default: the registry's
        ``REPRO_METRICS_PATH``).  ``*.prom`` targets are overwritten with
        the Prometheus text format; anything else gets one appended JSON
        line per call (``{"t": ..., "version": ..., "metrics": ...}``).
        Returns the path written, or None when no path is configured."""
        path = path or self.default_path
        if not path:
            return None
        if path.endswith(".prom"):
            with open(path, "w") as f:
                f.write(self.to_prometheus())
        else:
            snap = self.snapshot()
            snap["t"] = self._clock()
            with open(path, "a") as f:
                f.write(json.dumps(snap) + "\n")
        return path
