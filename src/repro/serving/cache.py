"""Cache administration utilities for the serving layer.

The cache pytrees themselves are built by ``models.lm.init_lm_cache``;
this module adds the operational pieces a serving deployment needs:
sizing (admission control), slot extraction/insertion, and host
offload/restore of individual slots (preemption & prefix reuse).

Offload blobs always carry FULL cache rows plus the slot's ``pos`` entry.
``pos`` doubles as the ring cursor of rolling sliding-window caches (slot
i holds the token with ``pos % window == i``), so a preempted request
restores bit-exactly even when the engine preempts it mid-window-wrap or
resumes it under a different KV bucket.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.core.memmodel import kv_cache_bytes, ssm_state_bytes


def cache_bytes(cfg: ModelConfig, batch: int, max_seq: int) -> int:
    """Analytic cache footprint — the serving admission controller's input."""
    return kv_cache_bytes(cfg, batch, max_seq) + ssm_state_bytes(cfg, batch)


def max_slots(cfg: ModelConfig, max_seq: int, hbm_budget: float,
              weight_bytes: float) -> int:
    """How many concurrent sequences fit next to the weights."""
    per_slot = cache_bytes(cfg, 1, max_seq)
    free = hbm_budget - weight_bytes
    return max(0, int(free // max(per_slot, 1)))


def extract_slot(cache: Any, b: int) -> Any:
    """Pull slot b out of a batched cache as a batch-1 cache (host copy)."""
    def pick(leaf):
        if leaf.ndim == 0:
            return leaf
        return jax.lax.dynamic_slice_in_dim(leaf, b, 1, axis=1)
    segs = [jax.tree_util.tree_map(pick, seg) for seg in cache["segments"]]
    # pos is [B] (batch on axis 0, unlike the [n_rep, B, ...] segment leaves)
    return {"segments": segs,
            "pos": jax.lax.dynamic_slice_in_dim(cache["pos"], b, 1, axis=0)}


def insert_slot(cache: Any, one: Any, b: int) -> Any:
    """Write a batch-1 cache into slot b (inverse of extract_slot)."""
    def ins(full, single):
        if full.ndim == 0:
            return full
        return jax.lax.dynamic_update_slice_in_dim(
            full, single.astype(full.dtype), b, axis=1)
    segs = [jax.tree_util.tree_map(ins, fs, ss)
            for fs, ss in zip(cache["segments"], one["segments"])]
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], one["pos"].astype(cache["pos"].dtype), b, axis=0)
    return {"segments": segs, "pos": pos}


def offload_slot(cache: Any, b: int) -> Dict[str, np.ndarray]:
    """Host-offload one slot (preempted request) as numpy arrays."""
    one = extract_slot(cache, b)
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(one):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def restore_slot(cache: Any, blob: Dict[str, np.ndarray], b: int) -> Any:
    """Re-admit a previously offloaded slot."""
    one = extract_slot(cache, b)   # template structure
    leaves = jax.tree_util.tree_leaves_with_path(one)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in leaves]
    vals = [jnp.asarray(blob[k]) for k in keys]
    treedef = jax.tree_util.tree_structure(one)
    restored = jax.tree_util.tree_unflatten(treedef, vals)
    return insert_slot(cache, restored, b)
