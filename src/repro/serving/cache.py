"""Cache administration utilities for the serving layer.

The cache pytrees themselves are built by ``models.lm.init_lm_cache``;
this module adds the operational pieces a serving deployment needs:
sizing (admission control), slot extraction/insertion, and host
offload/restore of individual slots (preemption & prefix reuse).

Offload blobs always carry FULL cache rows plus the slot's ``pos`` entry.
``pos`` doubles as the ring cursor of rolling sliding-window caches (slot
i holds the token with ``pos % window == i``), so a preempted request
restores bit-exactly even when the engine preempts it mid-window-wrap or
resumes it under a different KV bucket.

Integrity: blobs carry a ``__meta__`` record — a per-key crc32 of the
payload bytes (bounded to the live prefix for attention KV leaves, whose
tail rows are zeros by construction and masked on read — see
:func:`_live_rows`), a per-key schema (shape + dtype), and a single crc32
fingerprint over the schema.  :func:`restore_slot` validates the key set
against the slot template (reporting the FULL missing/extra diff), then
each key's schema and checksum, and raises
:class:`repro.serving.faults.CacheCorruption` naming the offending key —
a bit-flipped or truncated preemption/checkpoint blob can never be
scattered silently into a live continuous-batching group.
"""
from __future__ import annotations

import json
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.core.memmodel import kv_cache_bytes, ssm_state_bytes
from repro.serving.faults import CacheCorruption

#: Reserved blob key holding the JSON integrity record (not a cache leaf).
BLOB_META_KEY = "__meta__"


def cache_bytes(cfg: ModelConfig, batch: int, max_seq: int) -> int:
    """Analytic cache footprint — the serving admission controller's input."""
    return kv_cache_bytes(cfg, batch, max_seq) + ssm_state_bytes(cfg, batch)


def max_slots(cfg: ModelConfig, max_seq: int, hbm_budget: float,
              weight_bytes: float) -> int:
    """How many concurrent sequences fit next to the weights."""
    per_slot = cache_bytes(cfg, 1, max_seq)
    free = hbm_budget - weight_bytes
    return max(0, int(free // max(per_slot, 1)))


def extract_slot(cache: Any, b: int) -> Any:
    """Pull slot b out of a batched cache as a batch-1 cache (host copy).

    Jitted (slot index traced): one dispatch for the whole pytree instead
    of one eager slice per leaf — periodic checkpointing calls this on
    the serving hot path, where per-leaf dispatch overhead dominated."""
    return _extract_slot_jit(cache, jnp.asarray(b, jnp.int32))


@jax.jit
def _extract_slot_jit(cache: Any, b: jax.Array) -> Any:
    def pick(leaf):
        if leaf.ndim == 0:
            return leaf
        return jax.lax.dynamic_slice_in_dim(leaf, b, 1, axis=1)
    segs = [jax.tree_util.tree_map(pick, seg) for seg in cache["segments"]]
    # pos is [B] (batch on axis 0, unlike the [n_rep, B, ...] segment leaves)
    return {"segments": segs,
            "pos": jax.lax.dynamic_slice_in_dim(cache["pos"], b, 1, axis=0)}


def insert_slot(cache: Any, one: Any, b: int) -> Any:
    """Write a batch-1 cache into slot b (inverse of extract_slot)."""
    def ins(full, single):
        if full.ndim == 0:
            return full
        return jax.lax.dynamic_update_slice_in_dim(
            full, single.astype(full.dtype), b, axis=1)
    segs = [jax.tree_util.tree_map(ins, fs, ss)
            for fs, ss in zip(cache["segments"], one["segments"])]
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], one["pos"].astype(cache["pos"].dtype), b, axis=0)
    return {"segments": segs, "pos": pos}


def _blob_schema(arrays: Dict[str, np.ndarray]) -> Dict[str, Any]:
    return {k: [list(a.shape), str(a.dtype)]
            for k, a in sorted(arrays.items())}


def slot_schema(cache: Any) -> Dict[str, Any]:
    """The blob schema (key -> [shape, dtype]) an :func:`offload_slot` of
    this cache produces, computed from leaf metadata alone — no device
    transfer.  The durable checkpoint store fingerprints this next to the
    config so an engine never rehydrates blobs shaped for a different
    cache layout."""
    out: Dict[str, Any] = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key == "pos":                         # [B]: batch on axis 0
            shape: Tuple[int, ...] = (1,)
        elif leaf.ndim == 0:
            shape = ()
        else:                                    # [n_rep, B, ...]
            shape = (leaf.shape[0], 1) + tuple(leaf.shape[2:])
        out[key] = [list(shape), str(leaf.dtype)]
    return {k: out[k] for k in sorted(out)}


def _schema_fingerprint(schema: Dict[str, Any]) -> str:
    return f"{zlib.crc32(json.dumps(schema, sort_keys=True).encode()):08x}"


def _payload_crc(a: np.ndarray) -> int:
    # buffer protocol, no tobytes() copy: checkpointing crc's every live
    # slot's full cache rows on the serving hot path
    return zlib.crc32(np.ascontiguousarray(a).reshape(-1).view(np.uint8))


def _live_rows(out: Dict[str, np.ndarray], pos: int) -> Dict[str, int]:
    """Which blob keys get prefix-bounded checksums, and how many rows.

    Attention KV leaves (``.../attn/k|v``, row axis 2 after slot slicing)
    are zero past the slot's live prefix by construction — rows are only
    ever written at ``pos`` and reads are masked to ``valid_len`` — so a
    checksum over the first ``min(pos, rows)`` rows covers every byte
    that can ever influence a restored slot's output.  Checkpointing
    crc's every live slot on the serving hot path; bounding the
    checksummed bytes to the live prefix is the same trick the KV bucket
    ladder plays on attention reads."""
    live: Dict[str, int] = {}
    for k, a in out.items():
        if (k.endswith(("attn/k", "attn/v")) and a.ndim > 2
                and 0 <= pos < a.shape[2]):
            live[k] = pos
    return live


def _payload_crc_live(a: np.ndarray, rows) -> int:
    if rows is None:
        return _payload_crc(a)
    return _payload_crc(np.ascontiguousarray(a[:, :, :rows]))


def _finalize_blob(out: Dict[str, np.ndarray],
                   tags: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    pos = int(out["pos"][0]) if "pos" in out else -1
    live = _live_rows(out, pos)
    schema = _blob_schema(out)
    blob: Dict[str, Any] = dict(out)
    meta = {
        "schema": schema,
        "fingerprint": _schema_fingerprint(schema),
        "crc": {k: _payload_crc_live(a, live.get(k))
                for k, a in out.items()},
    }
    if live:
        meta["live"] = live
    if tags:
        meta["tags"] = dict(tags)
    blob[BLOB_META_KEY] = json.dumps(meta)
    return blob


def blob_tags(blob: Dict[str, Any]) -> Dict[str, Any]:
    """The caller-supplied identity/class tags a blob was offloaded with
    (``{"rid": ..., "priority": ...}`` from the engine), or {} for legacy
    blobs.  Unreadable meta raises the same CacheCorruption restore
    would."""
    meta_raw = blob.get(BLOB_META_KEY)
    if meta_raw is None:
        return {}
    try:
        return dict(json.loads(meta_raw).get("tags") or {})
    except (ValueError, AttributeError, TypeError) as e:
        raise CacheCorruption(
            f"unreadable blob __meta__ record: {e}") from None


def _blob_nbytes(blob: Dict[str, Any]) -> int:
    return sum(v.nbytes for v in blob.values() if hasattr(v, "nbytes"))


def _count_bytes(metrics, name: str, nbytes: int) -> None:
    """Optional metrics hook (a :class:`repro.serving.metrics
    .MetricsRegistry`): get-or-create is one dict lookup, so threading it
    through the offload/restore hot path costs nothing when unset."""
    if metrics is not None:
        metrics.counter(name, "host<->device cache traffic").inc(nbytes)


def offload_slot(cache: Any, b: int, metrics=None,
                 tags: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Host-offload one slot (preempted request / periodic checkpoint) as
    numpy arrays, plus a ``__meta__`` integrity record (per-key crc32 +
    schema fingerprint) that :func:`restore_slot` validates.  ``tags``
    (JSON-able, e.g. ``{"rid": ..., "priority": ...}``) ride in the meta
    record so a blob stays attributable to its request and priority
    class after the engine that wrote it is gone — and so restore can
    refuse a blob that was offloaded for a different request."""
    one = jax.device_get(extract_slot(cache, b))   # one batched transfer
    out: Dict[str, Any] = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(one):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    blob = _finalize_blob(out, tags=tags)
    _count_bytes(metrics, "repro_offload_bytes_total", _blob_nbytes(blob))
    return blob


def offload_slots(cache: Any, bs, metrics=None,
                  tags: Optional[Dict[int, Dict[str, Any]]] = None
                  ) -> Dict[int, Dict[str, Any]]:
    """Host-offload SEVERAL slots at once (the periodic checkpoint path):
    one ``device_get`` of the whole cache, then per-slot numpy slicing on
    the host — per-leaf dispatch/transfer overhead is paid once for the
    batch instead of once per slot.  Each returned blob is bit-identical
    to an :func:`offload_slot` call for the same slot (same keys, same
    ``__meta__`` record), so restore/validate treat them identically.
    ``tags`` maps slot index -> that slot's tag dict."""
    host = jax.device_get(cache)
    leaves = jax.tree_util.tree_leaves_with_path(host)
    keyed = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        keyed.append((key, np.asarray(leaf)))
    blobs: Dict[int, Dict[str, Any]] = {}
    for b in bs:
        out: Dict[str, np.ndarray] = {}
        for key, arr in keyed:
            if key == "pos":                     # [B]: batch on axis 0
                out[key] = arr[b:b + 1].copy()
            elif arr.ndim == 0:
                out[key] = arr
            else:                                # [n_rep, B, ...]
                out[key] = arr[:, b:b + 1].copy()
        blobs[b] = _finalize_blob(out, tags=(tags or {}).get(b))
        _count_bytes(metrics, "repro_offload_bytes_total",
                     _blob_nbytes(blobs[b]))
    return blobs


def validate_blob(blob: Dict[str, Any], template_keys,
                  rid=None) -> Dict[str, np.ndarray]:
    """Check a blob's key set against ``template_keys`` and its payload
    against its own ``__meta__`` record.  Returns the payload dict (meta
    stripped); raises :class:`CacheCorruption` on the first violation —
    key-set mismatches report the full missing/extra diff, schema and
    checksum mismatches name the offending key."""
    data = {k: v for k, v in blob.items() if k != BLOB_META_KEY}
    got, want = set(data), set(template_keys)
    if got != want:
        missing = sorted(want - got)
        extra = sorted(got - want)
        raise CacheCorruption(
            "blob key set does not match the slot template: "
            f"missing={missing or '[]'} extra={extra or '[]'}", rid=rid)
    meta_raw = blob.get(BLOB_META_KEY)
    if meta_raw is None:
        return data                  # legacy blob: key-set check only
    try:
        meta = json.loads(meta_raw)
        schema, crcs = meta["schema"], meta["crc"]
        fingerprint = meta["fingerprint"]
        live = meta.get("live", {})
    except (ValueError, KeyError, TypeError) as e:
        raise CacheCorruption(f"unreadable blob __meta__ record: {e}",
                              rid=rid) from None
    if fingerprint != _schema_fingerprint(schema):
        raise CacheCorruption("blob schema fingerprint mismatch "
                              f"(recorded {fingerprint})", rid=rid)
    for k in sorted(data):
        a = data[k]
        decl = schema.get(k)
        if decl is None or decl != [list(a.shape), str(a.dtype)]:
            raise CacheCorruption(
                f"schema mismatch: got {a.shape}/{a.dtype}, blob declares "
                f"{decl}", rid=rid, key=k)
        rows = live.get(k)
        if rows is not None and not (
                a.ndim > 2 and 0 <= int(rows) < a.shape[2]):
            raise CacheCorruption(
                f"blob declares live-prefix crc over {rows} rows, which "
                f"does not fit shape {a.shape}", rid=rid, key=k)
        if _payload_crc_live(a, rows) != crcs.get(k):
            raise CacheCorruption("payload crc32 mismatch", rid=rid, key=k)
    return data


def restore_slot(cache: Any, blob: Dict[str, Any], b: int,
                 rid=None, metrics=None,
                 expect_tags: Optional[Dict[str, Any]] = None) -> Any:
    """Re-admit a previously offloaded slot.  The blob is validated first
    (:func:`validate_blob`): a malformed or bit-flipped blob raises
    :class:`CacheCorruption` describing exactly what is wrong instead of
    a bare ``KeyError`` / silent garbage scatter.  ``expect_tags`` pins
    identity: every given key must match the blob's recorded tag (legacy
    tag-less blobs pass) — restoring request A's slot from request B's
    blob is corruption even when every checksum is intact."""
    if expect_tags:
        tags = blob_tags(blob)
        for k, v in expect_tags.items():
            if k in tags and tags[k] != v:
                raise CacheCorruption(
                    f"blob identity tag {k!r} mismatch: blob carries "
                    f"{tags[k]!r}, restore expects {v!r}", rid=rid)
    one = extract_slot(cache, b)   # template structure
    leaves = jax.tree_util.tree_leaves_with_path(one)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in leaves]
    data = validate_blob(blob, keys, rid=rid)
    for k, (_, tmpl) in zip(keys, leaves):
        if tuple(data[k].shape) != tuple(tmpl.shape):
            raise CacheCorruption(
                f"blob leaf shape {data[k].shape} does not fit the slot "
                f"template {tuple(tmpl.shape)}", rid=rid, key=k)
    vals = [jnp.asarray(data[k]) for k in keys]
    treedef = jax.tree_util.tree_structure(one)
    restored = jax.tree_util.tree_unflatten(treedef, vals)
    _count_bytes(metrics, "repro_restore_bytes_total", _blob_nbytes(data))
    return insert_slot(cache, restored, b)
