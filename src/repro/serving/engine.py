"""Serving runtime: prefill / decode step builders + a slot-based batch
engine (continuous batching with interleaved chunked prefill).

``serve_step`` (the decode shape lowered by the dry-run) is one new token
against a KV/state cache of the workload's seq_len, exactly per the
assignment.  The engine keeps a fixed batch of slots; finished sequences
are replaced by newly prefilled prompts whose per-layer cache slices are
scattered into the batch cache.

Decode is the fused on-device loop (:func:`repro.models.lm.decode_tokens`):
each engine iteration advances every live slot by ``decode_block`` tokens
inside one compiled ``lax.scan`` — on-device argmax, a single
device->host transfer per block instead of one per token.  The cache
carries a per-slot ``pos`` vector, so slots admitted at different times
decode at their own offsets (no shared position counter).

Admission runs through the chunked-prefill subsystem
(:mod:`repro.serving.prefill`) for EVERY decodable architecture — dense,
rolling sliding-window, SSM, hybrid, windowed-hybrid: queued prompts of
heterogeneous lengths form one padded group, and every engine iteration
runs exactly ONE prefill chunk interleaved with the decode burst — a
57K-token prompt can no longer stall the decoding slots behind a
monolithic O(L) prefill.  Rolling-window layers prefill into their
ring-buffer caches chunk-by-chunk (modular scatter + ring-unrolling
mask); there is no separate one-shot admission pipeline anymore.  When
the queue is starved of slots, the engine preempts the live slot with
the most remaining decode work (host offload via
:mod:`repro.serving.cache` — the ring cursor travels inside the offloaded
``pos``) and restores it once a slot frees up.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.distributed.sharding import ShardingPlan
from repro.models.lm import (decode_tokens, init_lm_cache, lm_decode_step,
                             lm_forward, lm_prefill)
from repro.serving.bucketing import (kv_cache_extent, rope_len_for,
                                     select_kv_bucket)
from repro.serving.cache import offload_slot, restore_slot
from repro.serving.prefill import ChunkedPrefill, supports_chunked_prefill


def make_prefill_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def prefill_step(params, inputs, cache):
        return lm_prefill(cfg, params, inputs, cache, kv_repeat=kv_repeat,
                          moe_groups=moe_groups)

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def decode_step(params, token, cache):
        return lm_decode_step(cfg, params, token, cache, kv_repeat=kv_repeat,
                              moe_groups=moe_groups)

    return decode_step


def make_decode_tokens(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    """Builder for the fused multi-token decode loop (jit with n static).

    ``rope_len`` (static) sizes the rope tables past the cache extent —
    rolling-window caches span only their window, but decode positions run
    to the serving ``max_seq``."""
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def decode_n(params, cache, first_token, n: int,
                 kv_bucket: Optional[int] = None,
                 rope_len: Optional[int] = None):
        return decode_tokens(cfg, params, cache, first_token, n,
                             kv_repeat=kv_repeat, moe_groups=moe_groups,
                             kv_bucket=kv_bucket, rope_len=rope_len)

    return decode_n


def make_encode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    """Encoder-only archs (hubert): one full forward is the serve step."""
    kv_repeat = plan.kv_repeat if plan else 1

    def encode_step(params, inputs):
        return lm_forward(cfg, params, inputs, kv_repeat=kv_repeat,
                          train=False)

    return encode_step


def greedy_generate(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
                    max_seq: int, gen_len: int,
                    plan: Optional[ShardingPlan] = None
                    ) -> Tuple[jax.Array, Any]:
    """Prefill + fused greedy decode: the whole generation burst runs as a
    single compiled program (no host round-trip per token)."""
    batch = next(iter(inputs.values())).shape[0]
    kv_repeat = plan.kv_repeat if plan else 1
    cache = init_lm_cache(cfg, batch, max_seq, kv_repeat=kv_repeat)
    prefill = jax.jit(make_prefill_step(cfg, plan))
    decode_n = jax.jit(make_decode_tokens(cfg, plan),
                       static_argnames=("n", "rope_len"))
    logits, cache = prefill(params, inputs, cache)
    first = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    if gen_len <= 1:
        return first, cache
    rest, cache = decode_n(params, cache, first, n=gen_len - 1,
                           rope_len=rope_len_for(cfg, max_seq))
    return jnp.concatenate([first, rest], axis=1), cache


# ---------------------------------------------------------------------------
# slot-based batch engine
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    # preemption state (set when the engine offloads this request's slot)
    blob: Optional[Dict[str, np.ndarray]] = None
    next_token: int = 0
    resume_pos: int = 0
    preemptions: int = 0


def _scatter_group(batch_cache, src_cache, dst: jax.Array):
    """Insert rows of a batch-k prefill cache into slots ``dst`` ([k]) of
    the batch cache in one call (per leaf the batch dim is axis 1: caches
    are stacked [n_rep, B, ...]).  Rows with ``dst[i] < 0`` are skipped
    (inert padding rows / rows emitted on an earlier chunk).  Jitted by
    the engine so a whole admission group lands in a single dispatch
    instead of one full-cache copy per request."""
    def ins(full, one):
        if full.ndim == 0 or one is None:
            return full

        def body(i, acc):
            d = jnp.clip(dst[i], 0, acc.shape[1] - 1)
            sl = jax.lax.dynamic_slice_in_dim(one, i, 1, axis=1)
            cur = jax.lax.dynamic_slice_in_dim(acc, d, 1, axis=1)
            sl = jnp.where(dst[i] >= 0, sl.astype(acc.dtype), cur)
            return jax.lax.dynamic_update_slice_in_dim(acc, sl, d, axis=1)

        return jax.lax.fori_loop(0, one.shape[1], body, full)
    segs = [jax.tree_util.tree_map(ins, fs, ss)
            for fs, ss in zip(batch_cache["segments"], src_cache["segments"])]
    return {"segments": segs, "pos": batch_cache["pos"]}


class ServingEngine:
    """Fixed-slot continuous batching over the fused decode loop.

    Each :meth:`step` runs one admission move — one chunk of the in-flight
    mixed-length prefill group, or a preempted-slot restore — then decodes
    ``decode_block`` tokens for every slot in one compiled loop.  Prefill
    and decode interleave: a long prompt prefilling chunk-by-chunk never
    blocks decode progress on live slots.  Per-slot ``pos`` means
    late-admitted slots attend only over their own valid cache rows.
    Every decodable architecture admits through this one path — encoder
    and audio-frontend configs have no autoregressive serving step and
    are rejected at construction.

    Attention work is bounded to the live prefix by static KV bucketing
    (:mod:`repro.serving.bucketing`): every decode burst and prefill chunk
    runs with the smallest power-of-two KV extent covering
    ``max(live pos) + block``, capped at the model's largest KV cache —
    ``max_seq`` for append-only caches, the *window* for rolling ones —
    so outputs stay bit-identical with O(log extent) compiled programs
    and FLOPs/IO that grow with the true context.

    When queued prompts are starved (no slot has freed for
    ``preempt_after`` iterations and no prefill is in flight), the live
    slot with the most remaining decode work is offloaded to host memory
    and requeued; it is restored — states, next token, position (which
    doubles as the rolling ring cursor: slot i of a rolling cache holds
    the token with ``pos % window == i``) — once a slot frees, and
    resumes exactly where it stopped.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_seq: int,
                 plan: Optional[ShardingPlan] = None, decode_block: int = 8,
                 chunk_size: Optional[int] = None, preempt_after: int = 4):
        if not supports_chunked_prefill(cfg):
            raise ValueError(
                f"{cfg.name}: no autoregressive serving path (encoder / "
                "audio-frontend architectures serve through "
                "make_encode_step, not the slot engine)")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.decode_block = decode_block
        kv_repeat = plan.kv_repeat if plan else 1
        self.cache = init_lm_cache(cfg, slots, max_seq, kv_repeat=kv_repeat)
        self._decode_n = jax.jit(make_decode_tokens(cfg, plan),
                                 static_argnames=("n", "kv_bucket",
                                                  "rope_len"))
        self._scatter = jax.jit(_scatter_group)
        self.kv_repeat = kv_repeat
        self.chunk_size = chunk_size or min(256, max_seq)
        self.preempt_after = preempt_after
        # bucket-ladder top: the model's largest KV extent (window-capped
        # for rolling archs); None = no KV cache worth bucketing
        self.kv_extent = kv_cache_extent(cfg, max_seq)
        self.kv_buckets = self.kv_extent is not None
        self.rope_len = rope_len_for(cfg, max_seq)
        self._chunked_prefill = ChunkedPrefill(
            cfg, params, max_seq=max_seq, chunk_size=self.chunk_size,
            plan=plan)
        # slots reserved for the in-flight prefill group: row i of the
        # group lands in slot _pending[i][0] when its prompt completes
        self._pending: List[Tuple[int, Request]] = []
        self._starved = 0
        self.live: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots, 1), np.int32)
        self.pos = np.zeros((slots,), np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.stats = {"iters": 0, "decode_tokens": 0, "prefill_chunks": 0,
                      "preemptions": 0, "restores": 0,
                      "interleave_iters": 0, "interleave_decode_iters": 0}
        # distinct KV buckets the decode loop has run in (bounded by the
        # bucket ladder — observability for the compile-count discipline)
        self.buckets_used: set = set()

    def submit(self, req: Request) -> None:
        # validate here, before admission can pop the request and reserve
        # slots: a mid-group failure would strand co-batched requests
        if len(req.prompt) == 0:
            raise ValueError(f"rid={req.rid}: empty prompt")
        # decode room is max_seq - 1 - pos, so a prompt needs at least two
        # cache rows beyond itself to emit any decoded token
        if len(req.prompt) > self.max_seq - 2:
            raise ValueError(
                f"rid={req.rid}: prompt length {len(req.prompt)} exceeds "
                f"max_seq-2 ({self.max_seq - 2}); no room to decode")
        self.queue.append(req)

    # ----------------------------------------------------------- admission
    def _restore(self, b: int, req: Request) -> None:
        """Re-admit a preempted request from its host-offloaded state."""
        self.cache = restore_slot(self.cache, req.blob, b)
        self.tokens[b, 0] = req.next_token
        self.pos[b] = req.resume_pos
        self.live[b] = req
        req.blob = None
        self.stats["restores"] += 1

    def _admit(self) -> None:
        reserved = {b for b, _ in self._pending}
        free = [b for b in range(self.slots)
                if self.live[b] is None and b not in reserved]
        ch = self._chunked_prefill
        # fill free slots from the queue in order: preempted requests are
        # restored in place (their cache is already prefilled+decoded),
        # fresh prompts accumulate into one mixed-length prefill group
        fresh: List[Request] = []
        while free and self.queue:
            req = self.queue[0]
            if req.blob is not None:
                self.queue.pop(0)
                self._restore(free.pop(0), req)
            elif not ch.active:
                self.queue.pop(0)
                fresh.append(req)
                self._pending.append((free.pop(0), req))
            else:  # a group is already in flight; keep the slot reserved
                break
        if fresh:
            ch.start([r.prompt for r in fresh],
                     batch=self.slots if len(fresh) > 1 else 1)
        if ch.active:
            emitted, done = ch.step()
            self._chunk_ran = True
            self.stats["prefill_chunks"] += 1
            if emitted:
                dst = np.full((len(self._pending),), -1, np.int32)
                for row, tok, plen in emitted:
                    b, req = self._pending[row]
                    dst[row] = b
                    req.out.append(tok)
                    self.tokens[b, 0] = tok
                    self.pos[b] = plen
                    self.live[b] = req
                # batch rows past the real group are inert (dst stays -1)
                full = np.full((ch.group_cache["pos"].shape[0],), -1,
                               np.int32)
                full[:len(dst)] = dst
                self.cache = self._scatter(self.cache, ch.group_cache,
                                           jnp.asarray(full))
            if done:
                ch.finish()
                self._pending = []
            self._starved = 0
        elif self.queue and not free:
            # queue starved: no slot freed and nothing is prefilling
            self._starved += 1
            if self._starved >= self.preempt_after:
                self._preempt()
        else:
            self._starved = 0

    def _preempt(self) -> None:
        """Offload the live slot with the most remaining decode work so a
        starved queued prompt can take its slot next iteration."""
        live = [(req.max_new - len(req.out), b)
                for b, req in enumerate(self.live) if req is not None]
        if not live:
            return
        _, b = max(live)
        req = self.live[b]
        self.cache = dict(self.cache, pos=jnp.asarray(self.pos, jnp.int32))
        req.blob = offload_slot(self.cache, b)
        req.next_token = int(self.tokens[b, 0])
        req.resume_pos = int(self.pos[b])
        req.preemptions += 1
        self.live[b] = None
        self.queue.append(req)
        self._starved = 0
        self.stats["preemptions"] += 1

    # ------------------------------------------------------------- decode
    def step(self) -> int:
        """One engine iteration: one admission move (prefill chunk /
        restore) interleaved with a ``decode_block`` burst for all live
        slots.  Returns live + queued + in-prefill."""
        self.stats["iters"] += 1
        self._chunk_ran = False
        self._admit()
        chunk_ran = self._chunk_ran
        if not any(req is not None for req in self.live):
            return len(self.queue) + len(self._pending)
        kblk = self.decode_block
        self.cache = dict(self.cache, pos=jnp.asarray(self.pos, jnp.int32))
        kv_bucket = None
        if self.kv_buckets:
            # bound the whole burst's attention to the live prefix: every
            # live slot reads/writes below max(pos) + decode_block, capped
            # at the extent ladder's top (rolling caches: the window —
            # their reads are already window-bounded past the cap).  Stale
            # pos of retired slots is excluded (their rows neither read
            # sensibly nor write at all inside the bucket).
            live_pos = [int(self.pos[b]) for b, r in enumerate(self.live)
                        if r is not None]
            kv_bucket = select_kv_bucket(
                min(max(live_pos) + kblk, self.kv_extent), self.kv_extent)
            self.buckets_used.add(kv_bucket)
        toks, self.cache = self._decode_n(self.params, self.cache,
                                          jnp.asarray(self.tokens), n=kblk,
                                          kv_bucket=kv_bucket,
                                          rope_len=self.rope_len)
        toks = np.asarray(toks)                     # one host sync per block
        n_live = 0
        decoded = 0
        for b, req in enumerate(self.live):
            if req is None:
                continue
            room = min(req.max_new - len(req.out),
                       self.max_seq - 1 - int(self.pos[b]))
            take = min(kblk, max(room, 0))
            req.out.extend(int(t) for t in toks[b, :take])
            decoded += take
            if take:
                self.tokens[b, 0] = int(toks[b, take - 1])
            self.pos[b] += take
            if len(req.out) >= req.max_new or self.pos[b] >= self.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.live[b] = None
            else:
                n_live += 1
        self.stats["decode_tokens"] += decoded
        if chunk_ran:
            # interleaving fairness: iterations where a prefill chunk ran
            # alongside live decode slots, and whether decode progressed
            self.stats["interleave_iters"] += 1
            if decoded:
                self.stats["interleave_decode_iters"] += 1
        return n_live + len(self.queue) + len(self._pending)

    def run(self) -> List[Request]:
        while self.step() or self.queue or self._pending:
            pass
        return self.finished
