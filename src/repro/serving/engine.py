"""Serving runtime: prefill / decode step builders + a slot-based batch
engine (continuous batching with interleaved chunked prefill and a
fault-tolerance layer).

``serve_step`` (the decode shape lowered by the dry-run) is one new token
against a KV/state cache of the workload's seq_len, exactly per the
assignment.  The engine keeps a fixed batch of slots; finished sequences
are replaced by newly prefilled prompts whose per-layer cache slices are
scattered into the batch cache.

Decode is the fused on-device loop (:func:`repro.models.lm.decode_tokens`):
each engine iteration advances every live slot by ``decode_block`` tokens
inside one compiled ``lax.scan`` — on-device argmax, a single
device->host transfer per block instead of one per token.  The cache
carries a per-slot ``pos`` vector, so slots admitted at different times
decode at their own offsets (no shared position counter).

Admission runs through the chunked-prefill subsystem
(:mod:`repro.serving.prefill`) for EVERY decodable architecture — dense,
rolling sliding-window, SSM, hybrid, windowed-hybrid: queued prompts of
heterogeneous lengths form one padded group, and every engine iteration
runs exactly ONE prefill chunk interleaved with the decode burst — a
57K-token prompt can no longer stall the decoding slots behind a
monolithic O(L) prefill.  Rolling-window layers prefill into their
ring-buffer caches chunk-by-chunk (modular scatter + ring-unrolling
mask); there is no separate one-shot admission pipeline anymore.

Scheduling DECISIONS — admission order, preemption urgency and victim
choice, deadline/starvation expiry, prefill interleave shares — are
delegated to a pluggable policy (:mod:`repro.serving.scheduler`:
``fifo`` / ``strict_tiers`` / ``weighted_fair`` over
``Request.priority`` classes, selected via ``REPRO_SCHED_POLICY``).
The engine keeps the MECHANISM: when the queue is starved of slots and
the policy names a victim, that slot is host-offloaded via
:mod:`repro.serving.cache` (the ring cursor travelling inside the
offloaded ``pos``, request identity and priority class riding in the
blob meta tags) and restored bit-exactly once a slot frees up.
Policies reorder work; they never change any request's decoded bytes.

Fault tolerance (:mod:`repro.serving.faults` is the taxonomy): every
request ends in a structured terminal state (``ok`` / ``failed`` /
``cancelled`` / ``timed_out``) on :attr:`ServingEngine.finished` — a
faulted request is quarantined and reported, never crashing the engine
or stranding its co-batched neighbours.  Decode bursts and prefill
chunks carry per-row on-device finiteness sentinels; a tripped slot is
restored from its last good checkpoint blob (periodic ``offload_slot``
every ``checkpoint_every`` bursts) and replayed once before failing with
``DivergenceDetected``.  Offload blobs are crc32/schema-validated on
restore (``CacheCorruption``), deadlines are enforced at admission and
in flight (``DeadlineExceeded``), and a no-progress watchdog
(``SlotStalled`` after ``stall_after`` zero-token iterations with work
queued) plus ``run(max_iters=...)`` bound the host loop.  All of it is
exercised deterministically via :mod:`repro.serving.fault_inject`
(``REPRO_FAULT_SPEC``).

Durability (:mod:`repro.serving.store`): with a ``CheckpointStore``
attached (``store=`` / ``store_dir=`` / ``REPRO_CHECKPOINT_DIR``), the
periodic checkpoint and preemption blobs — and every request's
metadata — persist to disk under an atomically-committed manifest.  A
fresh engine constructed over a populated store **rehydrates**: live
requests resume from their last durable blob (bad blobs degrade to
replay-from-prompt), queued ones re-enter with their original priority
and remaining deadline budget, and the resumed token streams are
bit-identical to an uninterrupted run.  Crashes are simulated
deterministically with ``kill`` fault clauses (``SimulatedCrash``).
"""
from __future__ import annotations

import logging
import os
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.distributed.sharding import ShardingPlan
from repro.models.lm import (decode_tokens, init_lm_cache, lm_decode_step,
                             lm_forward, lm_prefill)
from repro.serving.bucketing import (clamped_bucket, kv_cache_extent,
                                     rope_len_for)
from repro.serving.cache import (blob_tags, offload_slot, offload_slots,
                                 restore_slot, slot_schema, validate_blob)
from repro.serving.fault_inject import FaultPlan, SimulatedCrash, poison_slot
from repro.serving.faults import (CacheCorruption, DeadlineExceeded,
                                  DivergenceDetected, RecoveryFailed,
                                  RequestError, SlotStalled,
                                  StarvationTimeout)
from repro.serving.store import CheckpointStore, layout_fingerprint
from repro.serving.metrics import MetricsRegistry
from repro.serving.prefill import ChunkedPrefill, supports_chunked_prefill
from repro.serving.profiler import Profiler
from repro.serving.scheduler import (Scheduler, VictimCandidate,
                                     make_scheduler)
from repro.serving.telemetry import Telemetry

log = logging.getLogger("repro.serving.engine")


def make_prefill_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def prefill_step(params, inputs, cache):
        return lm_prefill(cfg, params, inputs, cache, kv_repeat=kv_repeat,
                          moe_groups=moe_groups)

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def decode_step(params, token, cache):
        return lm_decode_step(cfg, params, token, cache, kv_repeat=kv_repeat,
                              moe_groups=moe_groups)

    return decode_step


def make_decode_tokens(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    """Builder for the fused multi-token decode loop (jit with n static).

    ``rope_len`` (static) sizes the rope tables past the cache extent —
    rolling-window caches span only their window, but decode positions run
    to the serving ``max_seq``.  ``with_sentinel`` (static) appends the
    per-row finiteness flag to the return."""
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def decode_n(params, cache, first_token, n: int,
                 kv_bucket: Optional[int] = None,
                 rope_len: Optional[int] = None,
                 with_sentinel: bool = False):
        return decode_tokens(cfg, params, cache, first_token, n,
                             kv_repeat=kv_repeat, moe_groups=moe_groups,
                             kv_bucket=kv_bucket, rope_len=rope_len,
                             with_sentinel=with_sentinel)

    return decode_n


def make_encode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    """Encoder-only archs (hubert): one full forward is the serve step."""
    kv_repeat = plan.kv_repeat if plan else 1

    def encode_step(params, inputs):
        return lm_forward(cfg, params, inputs, kv_repeat=kv_repeat,
                          train=False)

    return encode_step


def greedy_generate(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
                    max_seq: int, gen_len: int,
                    plan: Optional[ShardingPlan] = None
                    ) -> Tuple[jax.Array, Any]:
    """Prefill + fused greedy decode: the whole generation burst runs as a
    single compiled program (no host round-trip per token)."""
    batch = next(iter(inputs.values())).shape[0]
    kv_repeat = plan.kv_repeat if plan else 1
    cache = init_lm_cache(cfg, batch, max_seq, kv_repeat=kv_repeat)
    prefill = jax.jit(make_prefill_step(cfg, plan))
    decode_n = jax.jit(make_decode_tokens(cfg, plan),
                       static_argnames=("n", "rope_len"))
    logits, cache = prefill(params, inputs, cache)
    first = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    if gen_len <= 1:
        return first, cache
    rest, cache = decode_n(params, cache, first, n=gen_len - 1,
                           rope_len=rope_len_for(cfg, max_seq))
    return jnp.concatenate([first, rest], axis=1), cache


# ---------------------------------------------------------------------------
# slot-based batch engine
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    deadline_ms: Optional[float] = None   # TTL from submit; None = no SLO
    priority: int = 0             # scheduling class; higher = more important
    out: List[int] = field(default_factory=list)
    done: bool = False
    status: str = "pending"       # terminal: ok/failed/cancelled/timed_out
    error: Optional[RequestError] = None
    submit_t: float = 0.0         # engine clock at submit (deadline base)
    # preemption state (set when the engine offloads this request's slot)
    blob: Optional[Dict[str, Any]] = None
    next_token: int = 0
    resume_pos: int = 0
    preemptions: int = 0
    # last-good checkpoint (divergence replay target)
    ckpt_blob: Optional[Dict[str, Any]] = None
    ckpt_token: int = 0
    ckpt_pos: int = 0
    ckpt_out: int = 0
    replays: int = 0


def _scatter_group(batch_cache, src_cache, dst: jax.Array):
    """Insert rows of a batch-k prefill cache into slots ``dst`` ([k]) of
    the batch cache in one call (per leaf the batch dim is axis 1: caches
    are stacked [n_rep, B, ...]).  Rows with ``dst[i] < 0`` are skipped
    (inert padding rows / rows emitted on an earlier chunk).  Jitted by
    the engine so a whole admission group lands in a single dispatch
    instead of one full-cache copy per request."""
    def ins(full, one):
        if full.ndim == 0 or one is None:
            return full

        def body(i, acc):
            d = jnp.clip(dst[i], 0, acc.shape[1] - 1)
            sl = jax.lax.dynamic_slice_in_dim(one, i, 1, axis=1)
            cur = jax.lax.dynamic_slice_in_dim(acc, d, 1, axis=1)
            sl = jnp.where(dst[i] >= 0, sl.astype(acc.dtype), cur)
            return jax.lax.dynamic_update_slice_in_dim(acc, sl, d, axis=1)

        return jax.lax.fori_loop(0, one.shape[1], body, full)
    segs = [jax.tree_util.tree_map(ins, fs, ss)
            for fs, ss in zip(batch_cache["segments"], src_cache["segments"])]
    return {"segments": segs, "pos": batch_cache["pos"]}


class ServingEngine:
    """Fixed-slot continuous batching over the fused decode loop.

    Each :meth:`step` runs one admission move — one chunk of the in-flight
    mixed-length prefill group, or a preempted-slot restore — then decodes
    ``decode_block`` tokens for every slot in one compiled loop.  Prefill
    and decode interleave: a long prompt prefilling chunk-by-chunk never
    blocks decode progress on live slots.  Per-slot ``pos`` means
    late-admitted slots attend only over their own valid cache rows.
    Every decodable architecture admits through this one path — encoder
    and audio-frontend configs have no autoregressive serving step and
    are rejected at construction.

    Attention work is bounded to the live prefix by static KV bucketing
    (:mod:`repro.serving.bucketing`): every decode burst and prefill chunk
    runs with the smallest power-of-two KV extent covering
    ``max(live pos) + block``, capped at the model's largest KV cache —
    ``max_seq`` for append-only caches, the *window* for rolling ones —
    so outputs stay bit-identical with O(log extent) compiled programs
    and FLOPs/IO that grow with the true context.

    When queued prompts are starved (no slot has freed for
    ``preempt_after`` iterations and no prefill is in flight — or
    immediately, when the policy reports a higher class waiting), the
    scheduler picks a victim from slack-costed candidates (estimated
    finish margin under the per-(phase, bucket) latency model;
    deadline-less slots rank as infinite slack): the default fifo rule
    evicts the most-slack slot tie-broken on max remaining decode work,
    strict tiers the lowest class, weighted fairness the class furthest
    over its share.  The victim is offloaded to host memory and
    requeued; it is restored bit-exactly once a slot frees.

    Scheduling policy (:mod:`repro.serving.scheduler`) is injected via
    ``scheduler=`` or built from ``sched_policy`` / ``sched_weights`` /
    ``starve_ms`` (environment: ``REPRO_SCHED_POLICY``,
    ``REPRO_SCHED_WEIGHTS``).  ``Request.priority`` is the class; the
    fifo default reproduces the engine's historical behaviour exactly.

    Failure handling (every knob below; taxonomy in
    :mod:`repro.serving.faults`):

    * ``sentinel`` — per-row on-device finiteness flags ride inside the
      decode scan and each prefill chunk.  A tripped decode row is
      restored from its last checkpoint and replayed once (bit-identical
      on transient faults), then failed with ``DivergenceDetected``; a
      tripped prefill row is quarantined out of its group.
    * ``checkpoint_every`` — every N engine iterations each live slot is
      offloaded as its replay target (plus once at admission); ``0``
      disables checkpointing (divergence then fails without replay).
    * ``Request.deadline_ms`` — TTL from submit.  Queued, mid-prefill and
      mid-decode expiries are cancelled (``timed_out``) and their slots
      reclaimed; admission rejects (``cancelled``) requests whose
      estimated latency under the per-(phase, KV-bucket) latency model
      (:attr:`telemetry`, steady-state samples only — first-dispatch
      compile spikes are segregated; ``estimate()``'s bucket-to-global
      fallback is the only fallback) exceeds the budget.
    * ``telemetry`` / ``trace_path`` — the structured metrics + tracing
      layer (:mod:`repro.serving.telemetry`): per-(phase, bucket)
      latency records and per-request span traces, JSONL-exported when
      ``trace_path`` (or ``REPRO_TRACE_PATH``) is set.  All engine
      timing, deadlines included, reads the one injectable ``clock``.
    * ``stall_after`` — no-progress watchdog: after N iterations with
      zero decoded tokens, no prefill progress and work still queued, the
      stranded requests fail with ``SlotStalled`` instead of hanging the
      host loop; :meth:`run` additionally takes ``max_iters``.
    * ``fault_plan`` — deterministic fault injection
      (:mod:`repro.serving.fault_inject`; defaults to the
      ``REPRO_FAULT_SPEC`` env plan) poking NaNs, blob bit-flips and
      prefill stalls at exact points so every path above is testable.

    Co-batch isolation invariant: rows are independent across the batch
    dim in every kernel, quarantine restores full slot rows, and failed
    slots are fully overwritten at re-admission — so a healthy request
    decodes bit-identically whether or not a neighbour slot faulted.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_seq: int,
                 plan: Optional[ShardingPlan] = None, decode_block: int = 8,
                 chunk_size: Optional[int] = None, preempt_after: int = 4,
                 checkpoint_every: int = 8, stall_after: int = 32,
                 sentinel: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 clock: Optional[Callable[[], float]] = None,
                 telemetry: Optional[Telemetry] = None,
                 trace_path: Optional[str] = None,
                 warmstart_path: Optional[str] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 profiler: Optional[Profiler] = None,
                 scheduler: Optional[Scheduler] = None,
                 sched_policy: Optional[str] = None,
                 sched_weights: Optional[Dict[int, float]] = None,
                 starve_ms: Optional[float] = None,
                 store: Optional[CheckpointStore] = None,
                 store_dir: Optional[str] = None):
        if not supports_chunked_prefill(cfg):
            raise ValueError(
                f"{cfg.name}: no autoregressive serving path (encoder / "
                "audio-frontend architectures serve through "
                "make_encode_step, not the slot engine)")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.decode_block = decode_block
        kv_repeat = plan.kv_repeat if plan else 1
        self.cache = init_lm_cache(cfg, slots, max_seq, kv_repeat=kv_repeat)
        self._decode_n = jax.jit(make_decode_tokens(cfg, plan),
                                 static_argnames=("n", "kv_bucket",
                                                  "rope_len",
                                                  "with_sentinel"))
        self._scatter = jax.jit(_scatter_group)
        self.kv_repeat = kv_repeat
        self.chunk_size = chunk_size or min(256, max_seq)
        self.preempt_after = preempt_after
        self.checkpoint_every = int(checkpoint_every)
        self.stall_after = int(stall_after)
        self.sentinel = bool(sentinel)
        self.faults = fault_plan if fault_plan is not None \
            else FaultPlan.from_env()
        # ALL scheduling DECISIONS — admission order, preemption victims,
        # deadline/starvation expiry, prefill interleave shares — live in
        # the policy object; the engine below is pure mechanism (dispatch,
        # scatter, offload/restore, terminal-state bookkeeping).  Policy
        # may reorder work but never changes any request's decoded bytes.
        self.scheduler = scheduler if scheduler is not None else \
            make_scheduler(sched_policy, sched_weights, starve_ms)
        self._clock = clock or time.monotonic
        # ALL engine timing — deadlines, dispatch latency, checkpoint cost
        # — reads this one clock, so fake-clock tests see consistent EWMAs.
        # The default Telemetry is keyed by this config's arch name (the
        # latency table never mixes rungs across archs) and warm-starts
        # from `warmstart_path` / REPRO_TELEMETRY_WARMSTART when set.
        self.telemetry = telemetry if telemetry is not None else Telemetry(
            clock=self._clock, trace_path=trace_path, arch=cfg.name,
            warmstart_path=warmstart_path)
        self.metrics = metrics if metrics is not None else MetricsRegistry(
            clock=self._clock)
        self.profiler = profiler if profiler is not None else Profiler(
            clock=self._clock)
        self._init_metrics()
        # bucket-ladder top: the model's largest KV extent (window-capped
        # for rolling archs); None = no KV cache worth bucketing
        self.kv_extent = kv_cache_extent(cfg, max_seq)
        self.kv_buckets = self.kv_extent is not None
        self.rope_len = rope_len_for(cfg, max_seq)
        self._chunked_prefill = ChunkedPrefill(
            cfg, params, max_seq=max_seq, chunk_size=self.chunk_size,
            plan=plan, sentinel=self.sentinel, fault_plan=self.faults,
            metrics=self.metrics)
        # slots reserved for the in-flight prefill group: row i of the
        # group lands in slot _pending[i][0] when its prompt completes
        self._pending: List[Tuple[int, Request]] = []
        self._starved = 0
        self._no_progress = 0
        # fractional-interleave accumulator: policies may grant the
        # in-flight prefill group < 1.0 chunk per iteration next to
        # higher-priority decode slots; credit accrues until a chunk runs
        self._prefill_credit = 0.0
        self.live: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots, 1), np.int32)
        self.pos = np.zeros((slots,), np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.stats = {"iters": 0, "decode_tokens": 0, "prefill_chunks": 0,
                      "preemptions": 0, "restores": 0,
                      "interleave_iters": 0, "interleave_decode_iters": 0,
                      "checkpoints": 0, "ckpt_ms": 0.0, "divergences": 0,
                      "replays": 0, "failures": 0, "timeouts": 0,
                      "cancelled": 0, "watchdog_trips": 0,
                      "starvation_timeouts": 0}
        # distinct KV buckets the decode loop has run in (bounded by the
        # bucket ladder — observability for the compile-count discipline)
        self.buckets_used: set = set()
        # decode bucket keys already dispatched (None included, for archs
        # without KV buckets): the FIRST dispatch per key pays XLA
        # trace+compile and its latency sample must be segregated from
        # the steady-state estimates feeding admission and preemption
        self._decode_seen: set = set()
        self._max_bucket = -1     # deepest decode rung seen (climb counter)
        # durable checkpoint store (crash recovery): explicit instance >
        # store_dir > REPRO_CHECKPOINT_DIR; None = host-memory-only FT.
        # A populated store rehydrates NOW — in-flight requests re-enter
        # as restore-from-blob admissions, queued ones with their
        # original priority and REMAINING deadline budget.
        if store is None:
            store_dir = store_dir or os.environ.get("REPRO_CHECKPOINT_DIR")
            store = CheckpointStore(store_dir) if store_dir else None
        self.store = store
        self._slot_schema = slot_schema(self.cache)
        self._template_keys = list(self._slot_schema)
        self._store_fp = layout_fingerprint(cfg.name, max_seq,
                                            self._slot_schema)
        self._store_order = 0
        self._rehydrate()

    def _init_metrics(self) -> None:
        """Register this engine's instruments on the (possibly shared)
        registry; get-or-create, so several engines can share one."""
        m = self.metrics
        self._m_queue = m.gauge(
            "repro_queue_depth", "requests waiting for a slot")
        self._m_live = m.gauge("repro_live_slots", "slots decoding now")
        self._m_tps = m.gauge(
            "repro_tokens_per_s", "steady-state token throughput per phase")
        self._m_submitted = m.counter(
            "repro_submitted_total", "requests submitted")
        self._m_admitted = m.counter(
            "repro_admitted_total", "requests admitted into a prefill group")
        self._m_finished = m.counter(
            "repro_finished_total",
            "terminal requests by status (ok/failed/cancelled/timed_out)")
        self._m_tokens = m.counter(
            "repro_tokens_total", "tokens processed per phase")
        self._m_preempt = m.counter(
            "repro_preemptions_total", "slot offloads for starved queues")
        self._m_restore = m.counter(
            "repro_restores_total", "preempted slots restored")
        self._m_ckpts = m.counter(
            "repro_checkpoints_total", "replay checkpoints taken")
        self._m_ckpt_bytes = m.counter(
            "repro_checkpoint_bytes_total",
            "host bytes offloaded by checkpointing")
        self._m_climbs = m.counter(
            "repro_bucket_climbs_total",
            "decode dispatches entering a deeper KV rung (each pays "
            "trace+compile)")
        self._m_diverg = m.counter(
            "repro_divergences_total", "sentinel trips")
        self._m_replays = m.counter(
            "repro_replays_total", "checkpoint replays after divergence")
        self._m_watchdog = m.counter(
            "repro_watchdog_trips_total", "no-progress watchdog trips")
        self._m_decode_ms = m.histogram(
            "repro_decode_burst_ms", "decode burst wall time (ms)")
        self._m_prefill_ms = m.histogram(
            "repro_prefill_chunk_ms", "prefill chunk wall time (ms)")
        self._m_ttft = m.histogram(
            "repro_ttft_ms",
            "time to first token (ms), labelled by priority class")
        self._m_class_tokens = m.counter(
            "repro_class_tokens_total",
            "tokens served per priority class and phase")
        self._m_starved = m.counter(
            "repro_starvation_timeouts_total",
            "queued requests failed by the scheduler's starvation bound")
        self._m_recoveries = m.counter(
            "repro_recoveries_total",
            "requests rehydrated from the durable checkpoint store at "
            "engine restart, by outcome (restored/replayed/requeued/"
            "expired/unrecoverable)")
        self._m_recovery_ms = m.histogram(
            "repro_recovery_ms",
            "wall time of one engine-restart rehydration pass (ms)")

    def submit(self, req: Request) -> None:
        # validate here, before admission can pop the request and reserve
        # slots: a mid-group failure would strand co-batched requests.
        # Submit-time ValueErrors are CALLER bugs and raise; in-flight
        # faults never do — they land on Request.status/.error instead.
        if len(req.prompt) == 0:
            raise ValueError(f"rid={req.rid}: empty prompt")
        # decode room is max_seq - 1 - pos, so a prompt needs at least two
        # cache rows beyond itself to emit any decoded token
        if len(req.prompt) > self.max_seq - 2:
            raise ValueError(
                f"rid={req.rid}: prompt length {len(req.prompt)} exceeds "
                f"max_seq-2 ({self.max_seq - 2}); no room to decode")
        p = np.asarray(req.prompt)
        if not np.issubdtype(p.dtype, np.integer):
            raise ValueError(f"rid={req.rid}: prompt dtype {p.dtype} is not "
                             "an integer token array")
        lo, hi = int(p.min()), int(p.max())
        if lo < 0 or hi >= self.cfg.vocab_size:
            raise ValueError(
                f"rid={req.rid}: prompt token ids [{lo}, {hi}] fall outside "
                f"the vocab [0, {self.cfg.vocab_size}) — out-of-vocab ids "
                "index garbage embedding rows")
        req.submit_t = self._clock()
        self.telemetry.begin_span(req.rid, prompt_len=len(req.prompt),
                                  max_new=req.max_new,
                                  deadline_ms=req.deadline_ms,
                                  priority=req.priority,
                                  t=req.submit_t)
        self.queue.append(req)
        self._m_submitted.inc()
        self._m_queue.set(len(self.queue))
        if self.store is not None:
            self._persist_request(req, state="queued")
            self.store.commit()

    # -------------------------------------------------------- durability
    def _persist_request(self, req: Request, *, state: str,
                         next_token: int = 0, pos: int = 0) -> None:
        """Write/refresh ``req``'s manifest record (uncommitted).  The
        record alone is enough to REPLAY the request from its prompt;
        with a staged blob it restores mid-stream.  ``age_ms`` (budget
        already consumed) + the persist-time clock reading let the next
        engine resurrect the deadline as *remaining* budget, and
        ``prompt_crc`` guards against a record whose replay would decode
        a different request."""
        p = np.asarray(req.prompt, np.int64)
        rec = self.store.record(
            req.rid, state=state,
            prompt=[int(x) for x in req.prompt],
            prompt_crc=zlib.crc32(p.tobytes()),
            max_new=int(req.max_new), priority=int(req.priority),
            deadline_ms=req.deadline_ms,
            age_ms=(self._clock() - req.submit_t) * 1e3, t=self._clock(),
            out=list(req.out), next_token=int(next_token), pos=int(pos))
        if "order" not in rec:           # admission order survives restart
            rec["order"] = self._store_order
            self._store_order += 1

    def _forget_request(self, req: Request) -> None:
        """Terminal state reached: the durable record (and its blob
        files, at the next prune) has nothing left to recover."""
        if self.store is not None:
            self.store.forget(req.rid)
            self.store.commit()

    def _rehydrate(self) -> None:
        """Resurrect a crashed engine's work from the durable store (at
        construction).  Per persisted record, in admission order:

        * expired while down — the consumed budget (pre-crash ``age_ms``
          + downtime) already exceeds ``deadline_ms``: fail with
          ``DeadlineExceeded`` NOW, before any replay work is wasted.
        * prompt fails its recorded crc32 — nothing can reproduce the
          original stream (``RecoveryFailed``); corrupt *blobs* are the
          recoverable case below, this is not.
        * in-flight with a durable checkpoint/preemption blob — validate
          it (crc/schema/identity-tag, exactly like a preemption
          restore); good blobs re-enter as restore-from-blob admissions
          with the already-decoded output reattached ("restored"), bad
          blobs degrade to replay-from-prompt ("replayed") — never a
          crash.
        * queued-but-unstarted — requeued with original priority
          ("requeued").

        ``submit_t`` is back-dated by the consumed budget so deadlines
        resume as REMAINING budget, not a fresh TTL.  Outcome counts
        land on :attr:`recovery` and ``repro_recoveries_total``."""
        self.recovery: Dict[str, int] = {
            "restored": 0, "replayed": 0, "requeued": 0,
            "expired": 0, "unrecoverable": 0}
        if self.store is None:
            return
        fp = self.store.manifest.get("fingerprint")
        if fp is not None and fp != self._store_fp:
            # a store written by a different config / cache geometry:
            # refuse to adopt it (rehydrating would scatter mis-shaped
            # rows; writing to it would corrupt the other engine's state)
            log.warning(
                "checkpoint store %s: layout fingerprint %s does not "
                "match this engine's %s (config %r, max_seq %d); "
                "ignoring the store", self.store.root, fp, self._store_fp,
                self.cfg.name, self.max_seq)
            self.store = None
            return
        t0 = self._clock()
        recs = sorted(self.store.requests.values(),
                      key=lambda r: r.get("order", 0))
        if recs:
            self._store_order = max(r.get("order", 0) for r in recs) + 1
        for rec in list(recs):
            rid = int(rec["rid"])
            prompt = np.asarray(rec.get("prompt") or [], np.int32)
            req = Request(rid=rid, prompt=prompt,
                          max_new=int(rec.get("max_new", 0)),
                          deadline_ms=rec.get("deadline_ms"),
                          priority=int(rec.get("priority", 0)))
            now = self._clock()
            # downtime on top of the budget consumed pre-crash; clamped
            # at 0 for clocks that restart from an earlier origin
            downtime_ms = max(0.0, (now - float(rec.get("t", now))) * 1e3)
            consumed_ms = float(rec.get("age_ms", 0.0)) + downtime_ms
            req.submit_t = now - consumed_ms / 1e3
            self.telemetry.begin_span(
                rid, prompt_len=len(prompt), max_new=req.max_new,
                deadline_ms=req.deadline_ms, priority=req.priority,
                t=req.submit_t, rehydrated=rec.get("state", "queued"))
            if (req.deadline_ms is not None
                    and consumed_ms >= req.deadline_ms):
                self.recovery["expired"] += 1
                self._m_recoveries.labels(outcome="expired").inc()
                self._fail(req, "timed_out", DeadlineExceeded(
                    f"deadline expired while the engine was down "
                    f"({consumed_ms:.1f}ms consumed of "
                    f"{req.deadline_ms:.1f}ms)", rid=rid))
                continue
            crc = rec.get("prompt_crc")
            if (len(prompt) == 0 or (crc is not None and int(crc) !=
                    zlib.crc32(np.asarray(prompt, np.int64).tobytes()))):
                self.recovery["unrecoverable"] += 1
                self._m_recoveries.labels(outcome="unrecoverable").inc()
                self._fail(req, "failed", RecoveryFailed(
                    "persisted prompt fails its recorded crc32 — replay "
                    "would decode a different request", rid=rid))
                continue
            outcome = "requeued"
            if rec.get("state") != "queued":
                outcome = "replayed"
                # only the NEWEST blob matches the record's resume point
                # (out/next_token/pos are persisted alongside it); any
                # failure degrades to replay-from-prompt
                rels = rec.get("blobs") or []
                if rels:
                    try:
                        blob = self.store.load_blob(rels[0])
                        validate_blob(blob, self._template_keys, rid=rid)
                        tags = blob_tags(blob)
                        if "rid" in tags and tags["rid"] != rid:
                            raise CacheCorruption(
                                f"durable blob carries rid {tags['rid']!r}",
                                rid=rid)
                        req.blob = blob
                        req.next_token = int(rec.get("next_token", 0))
                        req.resume_pos = int(rec.get("pos", 0))
                        req.out = [int(x) for x in rec.get("out") or []]
                        outcome = "restored"
                    except CacheCorruption as e:
                        log.warning("rid=%d: durable blob rejected (%s); "
                                    "replaying from prompt", rid, e)
            self.queue.append(req)
            self.recovery[outcome] += 1
            self._m_recoveries.labels(outcome=outcome).inc()
            self.telemetry.event(rid, "rehydrate", detail=outcome)
        self.store.set_fingerprint(self._store_fp)
        self.store.commit()
        self._m_queue.set(len(self.queue))
        if recs:
            self._m_recovery_ms.observe((self._clock() - t0) * 1e3)

    # ------------------------------------------------------------ failures
    def _fail(self, req: Request, status: str,
              err: Optional[RequestError]) -> None:
        """Move a request to a non-ok terminal state (never raises)."""
        req.status = status
        req.error = err
        req.done = True
        req.blob = None
        req.ckpt_blob = None
        self.finished.append(req)
        self.telemetry.end_span(req.rid, status,
                                error=str(err) if err else None,
                                tokens_out=len(req.out))
        self.stats[{"failed": "failures", "timed_out": "timeouts",
                    "cancelled": "cancelled"}[status]] += 1
        self._m_finished.labels(status=status).inc()
        self._forget_request(req)

    def _expired(self, req: Request, now: float) -> bool:
        return self.scheduler.expired(req, now)

    def _expire_deadlines(self) -> None:
        """Cancel queued / mid-prefill / mid-decode requests whose TTL has
        run out (the scheduler owns the expiry decision; reclaiming slots
        and group rows is mechanism and happens here), then fail queued
        requests the policy's starvation bound has given up on."""
        now = self._clock()
        for req in [r for r in self.queue if self._expired(r, now)]:
            self.queue.remove(req)
            self._fail(req, "timed_out", DeadlineExceeded(
                "deadline expired while queued "
                f"({req.deadline_ms:.1f}ms)", rid=req.rid))
        for row, (b, req) in enumerate(self._pending):
            if not req.done and self._expired(req, now):
                self._chunked_prefill.cancel_row(row)
                self._fail(req, "timed_out", DeadlineExceeded(
                    "deadline expired mid-prefill "
                    f"({req.deadline_ms:.1f}ms)", rid=req.rid))
        for b, req in enumerate(self.live):
            if req is not None and self._expired(req, now):
                self.live[b] = None
                self._fail(req, "timed_out", DeadlineExceeded(
                    "deadline expired mid-decode after "
                    f"{len(req.out)} tokens ({req.deadline_ms:.1f}ms)",
                    rid=req.rid))
        for req in self.scheduler.starved_out(self.queue, self.live, now):
            self.queue.remove(req)
            wait_ms = (now - req.submit_t) * 1e3
            self._fail(req, "timed_out", StarvationTimeout(
                f"class-{req.priority} request starved for {wait_ms:.1f}ms "
                f"(> {self.scheduler.starve_ms:.1f}ms bound) behind "
                "higher-priority work", rid=req.rid))
            self.stats["starvation_timeouts"] += 1
            self._m_starved.inc()

    def _admission_estimate_ms(self, req: Request) -> Optional[float]:
        """Latency estimate from the per-(phase, bucket) latency model:
        prefill cost at the rung covering the prompt, decode cost at the
        rung the request will finish under (conservative — the deepest
        bucket it reaches).  ``estimate()`` itself falls back from the
        bucket to the phase-global steady record (never across archs,
        never to compile samples) — that is the ONLY fallback; None until
        either phase has a steady-state measurement."""
        plen, mnew = len(req.prompt), req.max_new
        ptok = self.telemetry.estimate(
            "prefill", clamped_bucket(plen, self.kv_extent))
        tpot = self.telemetry.estimate(
            "decode", clamped_bucket(plen + mnew, self.kv_extent))
        if ptok is None and tpot is None:
            return None
        return plen * (ptok or 0.0) + mnew * (tpot or 0.0)

    # ----------------------------------------------------------- admission
    def _restore(self, b: int, req: Request) -> bool:
        """Re-admit a preempted request from its host-offloaded state.
        A corrupted blob fails the REQUEST (CacheCorruption), not the
        engine; returns False and leaves the slot free."""
        try:
            self.cache = restore_slot(self.cache, req.blob, b, rid=req.rid,
                                      metrics=self.metrics,
                                      expect_tags={"rid": req.rid})
        except CacheCorruption as e:
            self._fail(req, "failed", e)
            return False
        self.tokens[b, 0] = req.next_token
        self.pos[b] = req.resume_pos
        self.live[b] = req
        # the validated preemption blob doubles as the replay checkpoint
        req.ckpt_blob = req.blob
        req.ckpt_token = req.next_token
        req.ckpt_pos = req.resume_pos
        req.ckpt_out = len(req.out)
        req.blob = None
        self.stats["restores"] += 1
        self._m_restore.inc()
        self.telemetry.event(req.rid, "restore", pos=req.resume_pos)
        return True

    def _admit(self, it: int) -> None:
        ch = self._chunked_prefill
        # a group whose every request already reached a terminal state
        # (deadline sweep, watchdog) is pure inert work: drop it
        if ch.active and self._pending and all(r.done
                                               for _, r in self._pending):
            ch.finish()
            self._pending = []
        reserved = {b for b, r in self._pending if not r.done}
        free = [b for b in range(self.slots)
                if self.live[b] is None and b not in reserved]
        # fill free slots from the queue in SCHEDULER order (fifo = submit
        # order, so the walk below reproduces the historical head-of-queue
        # loop exactly): preempted requests are restored in place (their
        # cache is already prefilled+decoded), fresh prompts accumulate
        # into one mixed-length prefill group.  A fresh prompt that can't
        # start (group already in flight) ends the walk — later requests
        # must not jump a reserved slot the policy ordered ahead of them.
        fresh: List[Request] = []
        order = self.scheduler.admission_order(self.queue, self._clock())
        for req in order:
            if not free:
                break
            if req.blob is not None:
                self.queue.remove(req)
                b = free.pop(0)
                if self._restore(b, req):
                    self._progress = True
                else:
                    free.insert(0, b)
            elif not ch.active:
                if req.deadline_ms is not None:
                    est = self._admission_estimate_ms(req)
                    left = (req.deadline_ms
                            - (self._clock() - req.submit_t) * 1e3)
                    if est is not None and est > left:
                        self.queue.remove(req)
                        self._fail(req, "cancelled", DeadlineExceeded(
                            f"admission reject: estimated {est:.1f}ms "
                            f"exceeds remaining {left:.1f}ms budget",
                            rid=req.rid))
                        continue
                self.queue.remove(req)
                fresh.append(req)
                self._pending.append((free.pop(0), req))
            else:  # a group is already in flight; keep the slot reserved
                break
        if fresh:
            ch.start([r.prompt for r in fresh],
                     batch=self.slots if len(fresh) > 1 else 1,
                     priorities=[r.priority for r in fresh])
            self._m_admitted.inc(len(fresh))
            self._m_queue.set(len(self.queue))
        stalled = self.faults.active and self.faults.stalled(it)
        run_chunk = ch.active and not stalled
        if run_chunk:
            # the policy may grant a low-priority group a fractional
            # iteration share next to higher-priority decode slots;
            # credit accrues until a whole chunk is due.  With no live
            # decode slot there is nothing to yield to: always run.
            live_cls = [r.priority for r in self.live if r is not None]
            share = 1.0 if not live_cls else min(1.0, max(
                0.0, self.scheduler.interleave_share(
                    [r.priority for _, r in self._pending if not r.done],
                    live_cls)))
            self._prefill_credit += share
            if self._prefill_credit >= 1.0:
                self._prefill_credit -= 1.0
            else:
                run_chunk = False
                self._starved = 0    # group in flight: queue isn't starved
        if run_chunk:
            t0 = self._clock()
            emitted, done, diverged = ch.step()
            dt_ms = (self._clock() - t0) * 1e3
            info = ch.last_chunk
            self._chunk_ran = True
            self._progress = True
            self.stats["prefill_chunks"] += 1
            # per-token cost over the group's VALID (unmasked) tokens —
            # dividing by the padded chunk size deflated the estimate on
            # ragged final chunks — recorded per (phase, bucket) with the
            # first dispatch of a (batch, bucket) combo segregated as a
            # compile sample (trace+compile must not poison steady state)
            if info["valid_tokens"] > 0:
                tok_ms = dt_ms / info["valid_tokens"]
                self.telemetry.record_latency(
                    "prefill", info["bucket"], tok_ms,
                    compiled=info["fresh_compile"])
                if not info["fresh_compile"] and tok_ms > 0:
                    self._m_tps.labels(phase="prefill").set(1e3 / tok_ms)
                self._m_tokens.labels(phase="prefill").inc(
                    info["valid_tokens"])
            self._m_prefill_ms.observe(dt_ms)
            self.profiler.observe("prefill", dt_ms)
            for row, (b, req) in enumerate(self._pending):
                if not req.done and info["valid_per_row"][row]:
                    tokens = int(info["valid_per_row"][row])
                    self.telemetry.event(
                        req.rid, "prefill", bucket=info["bucket"],
                        tokens=tokens)
                    # DRR debit: prefill work counts against the class's
                    # weighted share exactly like decode tokens do
                    self.scheduler.note_service(req.priority, tokens)
                    self._m_class_tokens.labels(
                        priority=str(req.priority), phase="prefill").inc(
                            tokens)
            for row in diverged:
                b, req = self._pending[row]
                if not req.done:
                    self.telemetry.event(req.rid, "fault",
                                         detail="prefill_divergence")
                    self._fail(req, "failed", DivergenceDetected(
                        "non-finite activations in prefill chunk "
                        f"{ch._group['idx'] - 1}", rid=req.rid))
            if emitted:
                dst = np.full((len(self._pending),), -1, np.int32)
                for row, tok, plen in emitted:
                    b, req = self._pending[row]
                    if req.done:             # expired/failed while pending
                        continue
                    dst[row] = b
                    req.out.append(tok)
                    self.tokens[b, 0] = tok
                    self.pos[b] = plen
                    self.live[b] = req
                    ttft = self.telemetry.first_token(req.rid)
                    if ttft is not None:
                        self._m_ttft.labels(
                            priority=str(req.priority)).observe(ttft)
                # batch rows past the real group are inert (dst stays -1)
                full = np.full((ch.group_cache["pos"].shape[0],), -1,
                               np.int32)
                full[:len(dst)] = dst
                self.cache = self._scatter(self.cache, ch.group_cache,
                                           jnp.asarray(full))
            if done:
                ch.finish()
                self._pending = []
            self._starved = 0
        elif self.queue and not free and not ch.active and not stalled:
            # queue starved: no slot freed and nothing is prefilling.
            # The policy can demand immediate preemption (strict tiers:
            # a higher class is waiting) instead of sitting out the
            # preempt_after starvation window.
            self._starved += 1
            if (self._starved >= self.preempt_after
                    or self.scheduler.urgent_preempt(self.queue, self.live)):
                self._preempt()
        elif not stalled and not ch.active:
            self._starved = 0

    def _preempt(self) -> None:
        """Offload one live slot so a starved queued prompt can take it
        next iteration.  The engine's part is MECHANISM: cost every live
        slot's deadline slack under the per-(phase, bucket) latency model
        (each slot's remaining decode costed at the rung it will finish
        under; deadline-less slots rank as infinite slack) and offload
        whichever slot the scheduler names.  Victim CHOICE is policy:
        fifo keeps the historical most-slack / most-remaining rule,
        strict tiers evict the lowest class, weighted fairness evicts the
        class furthest over its share."""
        now = self._clock()
        candidates: List[VictimCandidate] = []
        for b, req in enumerate(self.live):
            if req is None:
                continue
            remaining = req.max_new - len(req.out)
            if req.deadline_ms is None:
                slack = float("inf")
            else:
                tpot = self.telemetry.estimate("decode", clamped_bucket(
                    int(self.pos[b]) + remaining, self.kv_extent)) or 0.0
                slack = (req.deadline_ms - (now - req.submit_t) * 1e3
                         - remaining * tpot)
            candidates.append(VictimCandidate(
                slot=b, priority=req.priority, slack=slack,
                remaining=remaining))
        b = self.scheduler.preempt_victim(candidates, self.queue)
        if b is None:
            return
        req = self.live[b]
        self.cache = dict(self.cache, pos=jnp.asarray(self.pos, jnp.int32))
        blob = offload_slot(self.cache, b, tags={
            "rid": req.rid, "priority": req.priority})
        if self.faults.active:
            blob = self.faults.corrupt_blob(req.rid, blob)
        req.blob = blob
        req.next_token = int(self.tokens[b, 0])
        req.resume_pos = int(self.pos[b])
        req.preemptions += 1
        if self.store is not None:
            # a preemption blob is already a consistent resume point —
            # persist it so a crash while the request sits requeued
            # restores mid-stream instead of replaying the whole prefix
            self.store.stage_blob(req.rid, blob)
            self._persist_request(req, state="preempted",
                                  next_token=req.next_token,
                                  pos=req.resume_pos)
            self.store.commit()
        self.telemetry.event(req.rid, "preempt", pos=int(self.pos[b]))
        self.live[b] = None
        self.queue.append(req)
        self._starved = 0
        self.stats["preemptions"] += 1
        self._m_preempt.inc()

    # --------------------------------------------------------- checkpoints
    def _checkpoint(self, it: int) -> None:
        """Periodic lightweight checkpointing: offload each live slot as
        its divergence-replay target.  Runs every ``checkpoint_every``
        iterations plus once at each request's first burst (so replay is
        possible before the first periodic tick).  Taken at burst START,
        where host ``pos``/``tokens`` and device cache rows agree."""
        if not self.checkpoint_every:
            return
        due = it % self.checkpoint_every == 0
        need = [(b, r) for b, r in enumerate(self.live)
                if r is not None and (due or r.ckpt_blob is None)]
        if not need:
            return
        t0 = self._clock()
        self.cache = dict(self.cache, pos=jnp.asarray(self.pos, jnp.int32))
        # one full-cache transfer for the whole batch of due slots: the
        # per-leaf dispatch overhead of slot-at-a-time offload dominated
        # the healthy-path checkpoint cost
        blobs = offload_slots(self.cache, [b for b, _ in need],
                              metrics=self.metrics,
                              tags={b: {"rid": r.rid, "priority": r.priority}
                                    for b, r in need})
        for b, req in need:
            blob = blobs[b]
            if self.faults.active:
                blob = self.faults.corrupt_blob(req.rid, blob)
            req.ckpt_blob = blob
            req.ckpt_token = int(self.tokens[b, 0])
            req.ckpt_pos = int(self.pos[b])
            req.ckpt_out = len(req.out)
            if self.store is not None:
                self.store.stage_blob(req.rid, blob)
                self._persist_request(req, state="live",
                                      next_token=req.ckpt_token,
                                      pos=req.ckpt_pos)
            self.stats["checkpoints"] += 1
            self._m_ckpts.inc()
            self._m_ckpt_bytes.inc(sum(
                v.nbytes for v in blob.values() if hasattr(v, "nbytes")))
            self.telemetry.event(req.rid, "checkpoint")
        if self.store is not None:
            # crash point 1: blob files staged, manifest commit not yet
            # landed — recovery must see the PREVIOUS manifest intact
            if self.faults.active and self.faults.kill_now(it, point=1):
                raise SimulatedCrash(
                    "fault injection: kill between checkpoint stage and "
                    f"manifest commit at iteration {it}")
            self.store.commit()
        # observability for the < 5% healthy-path overhead budget: the
        # fault smoke gates on ckpt_ms / wall time
        self.stats["ckpt_ms"] += (self._clock() - t0) * 1e3

    def _quarantine(self, b: int, req: Request) -> None:
        """Divergence sentinel tripped for slot ``b`` this burst: none of
        the burst's tokens are accepted.  Restore the slot from its last
        good checkpoint and replay once; on a second trip (or with
        checkpointing disabled / a corrupt checkpoint) fail the request
        with ``DivergenceDetected`` — co-batched slots are untouched
        either way."""
        self.stats["divergences"] += 1
        self._m_diverg.inc()
        self.telemetry.event(req.rid, "fault", detail="decode_divergence")
        if (self.checkpoint_every and req.ckpt_blob is not None
                and req.replays < 1):
            try:
                self.cache = restore_slot(self.cache, req.ckpt_blob, b,
                                          rid=req.rid, metrics=self.metrics,
                                          expect_tags={"rid": req.rid})
            except CacheCorruption as e:
                self.live[b] = None
                self._fail(req, "failed", e)
                return
            self.tokens[b, 0] = req.ckpt_token
            self.pos[b] = req.ckpt_pos
            del req.out[req.ckpt_out:]
            req.replays += 1
            self.stats["replays"] += 1
            self._m_replays.inc()
            self.telemetry.event(req.rid, "replay", pos=req.ckpt_pos)
        else:
            self.live[b] = None
            self._fail(req, "failed", DivergenceDetected(
                "non-finite logits in decode burst"
                + (" after checkpoint replay" if req.replays else
                   " (no checkpoint to replay)"), rid=req.rid))

    # ------------------------------------------------------------ watchdog
    def _watchdog(self, decoded: int) -> None:
        waiting = bool(self.queue) or any(not r.done
                                          for _, r in self._pending)
        if decoded or self._progress or not waiting:
            self._no_progress = 0
            return
        self._no_progress += 1
        if self._no_progress < self.stall_after:
            return
        self._no_progress = 0
        self.stats["watchdog_trips"] += 1
        self._m_watchdog.inc()
        stuck = [(row, req) for row, (b, req) in enumerate(self._pending)
                 if not req.done]
        if stuck:
            for row, req in stuck:
                self._chunked_prefill.cancel_row(row)
                self._fail(req, "failed", SlotStalled(
                    f"no progress for {self.stall_after} iterations with "
                    "prefill in flight", rid=req.rid))
            if self._chunked_prefill.active:
                self._chunked_prefill.finish()
            self._pending = []
        elif self.queue:
            req = self.queue.pop(0)
            self._fail(req, "failed", SlotStalled(
                f"no progress for {self.stall_after} iterations at the "
                "head of the queue", rid=req.rid))

    def _open_pending(self) -> int:
        return sum(1 for _, r in self._pending if not r.done)

    # ------------------------------------------------------------- decode
    def step(self) -> int:
        """One engine iteration: one admission move (prefill chunk /
        restore) interleaved with a ``decode_block`` burst for all live
        slots.  Returns live + queued + in-prefill (terminal requests
        excluded).  Never raises for in-flight faults — failing requests
        land on :attr:`finished` with a structured status."""
        it = self.stats["iters"]
        # crash point 0: between iterations, before any state mutates —
        # everything committed through iteration it-1 must recover
        if self.faults.active and self.faults.kill_now(it):
            raise SimulatedCrash(
                f"fault injection: kill at engine iteration {it}")
        self.stats["iters"] += 1
        self._chunk_ran = False
        self._progress = False
        self._expire_deadlines()
        self._admit(it)
        chunk_ran = self._chunk_ran
        if not any(req is not None for req in self.live):
            self._watchdog(decoded=0)
            return len(self.queue) + self._open_pending()
        self._checkpoint(it)
        if self.faults.active:
            for b in self.faults.nan_decode_slots(it):
                if 0 <= b < self.slots:
                    self.cache = poison_slot(self.cache, b)
        kblk = self.decode_block
        self.cache = dict(self.cache, pos=jnp.asarray(self.pos, jnp.int32))
        kv_bucket = None
        if self.kv_buckets:
            # bound the whole burst's attention to the live prefix: every
            # live slot reads/writes below max(pos) + decode_block, capped
            # at the extent ladder's top (rolling caches: the window —
            # their reads are already window-bounded past the cap).  Stale
            # pos of retired slots is excluded (their rows neither read
            # sensibly nor write at all inside the bucket).
            live_pos = [int(self.pos[b]) for b, r in enumerate(self.live)
                        if r is not None]
            kv_bucket = clamped_bucket(max(live_pos) + kblk, self.kv_extent)
            self.buckets_used.add(kv_bucket)
        # the first dispatch per bucket key (None included — archs without
        # KV buckets still compile on their first burst) pays trace+compile
        fresh_compile = kv_bucket not in self._decode_seen
        self._decode_seen.add(kv_bucket)
        if kv_bucket is not None and kv_bucket > self._max_bucket:
            if self._max_bucket >= 0:
                self._m_climbs.inc()
            self._max_bucket = kv_bucket
        t0 = self._clock()
        out = self._decode_n(self.params, self.cache,
                             jnp.asarray(self.tokens), n=kblk,
                             kv_bucket=kv_bucket, rope_len=self.rope_len,
                             with_sentinel=self.sentinel)
        if self.sentinel:
            toks_d, self.cache, ok_d = out
            # ONE host sync per block: tokens and sentinel flags fetched
            # in a single batched transfer, not two round-trips
            toks, okh = jax.device_get((toks_d, ok_d))
        else:
            toks_d, self.cache = out
            toks = np.asarray(toks_d)
            okh = None
        dt_ms = (self._clock() - t0) * 1e3
        # per-token latency feeds the deadline admission controller and
        # preemption slack ordering, keyed by (phase, bucket); the first
        # dispatch per bucket is tagged a compile sample and segregated —
        # a bucket-ladder climb must not poison the steady-state estimate
        # (it used to: fresh_compile was computed but never gated here)
        self.telemetry.record_latency("decode", kv_bucket, dt_ms / kblk,
                                      compiled=fresh_compile)
        self._m_decode_ms.observe(dt_ms)
        self.profiler.observe("decode", dt_ms)
        if not fresh_compile and dt_ms > 0:
            self._m_tps.labels(phase="decode").set(kblk * 1e3 / dt_ms)
        n_live = 0
        decoded = 0
        for b, req in enumerate(self.live):
            if req is None:
                continue
            if okh is not None and not bool(okh[b]):
                self._quarantine(b, req)
                if self.live[b] is not None:
                    n_live += 1
                continue
            room = min(req.max_new - len(req.out),
                       self.max_seq - 1 - int(self.pos[b]))
            take = min(kblk, max(room, 0))
            req.out.extend(int(t) for t in toks[b, :take])
            decoded += take
            if take:
                self.tokens[b, 0] = int(toks[b, take - 1])
                self.telemetry.event(req.rid, "decode", bucket=kv_bucket,
                                     tokens=take)
                self.scheduler.note_service(req.priority, take)
                self._m_class_tokens.labels(
                    priority=str(req.priority), phase="decode").inc(take)
            self.pos[b] += take
            if len(req.out) >= req.max_new or self.pos[b] >= self.max_seq - 1:
                req.done = True
                req.status = "ok"
                req.ckpt_blob = None
                self.finished.append(req)
                self._m_finished.labels(status="ok").inc()
                self.telemetry.end_span(req.rid, "ok",
                                        tokens_out=len(req.out))
                self._forget_request(req)
                self.live[b] = None
            else:
                n_live += 1
        self.stats["decode_tokens"] += decoded
        self._m_tokens.labels(phase="decode").inc(decoded)
        self._m_live.set(n_live)
        self._m_queue.set(len(self.queue))
        if chunk_ran:
            # interleaving fairness: iterations where a prefill chunk ran
            # alongside live decode slots, and whether decode progressed
            self.stats["interleave_iters"] += 1
            if decoded:
                self.stats["interleave_decode_iters"] += 1
        self._watchdog(decoded)
        return n_live + len(self.queue) + self._open_pending()

    def run(self, max_iters: Optional[int] = None) -> List[Request]:
        """Drive :meth:`step` until all work reaches a terminal state.
        ``max_iters`` is the escape hatch over the watchdog: past it, all
        in-flight and queued requests are cancelled (``SlotStalled``
        records the bound) and the engine returns instead of hanging."""
        try:
            while self.step() or self.queue or self._open_pending():
                if max_iters is not None and self.stats["iters"] >= max_iters:
                    self._abort_inflight("cancelled", SlotStalled(
                        f"run(max_iters={max_iters}) exhausted with work "
                        "outstanding"))
                    break
        finally:
            # persist the measured latency model for the next process and
            # flush metrics — both no-ops unless a path is configured
            self.telemetry.save_warmstart()
            self.metrics.export()
            if self.store is not None:
                self.store.commit()
        return self.finished

    def profile_snapshot(self) -> Dict[str, Any]:
        """The profiler's per-kernel-family attribution.  In coarse mode
        the representative decode program is registered lazily here (its
        lowering cost lands on the caller asking for shares, never on the
        serving hot path)."""
        if (self.profiler.mode == "coarse"
                and not self.profiler.registered("decode")
                and self._decode_seen):
            kv_bucket = max((b for b in self._decode_seen if b is not None),
                            default=None)
            # re-lowering through the engine's own jitted wrapper hits the
            # executable cache for shapes the loop already ran
            lowered = self._decode_n.lower(
                self.params, self.cache, jnp.asarray(self.tokens),
                n=self.decode_block, kv_bucket=kv_bucket,
                rope_len=self.rope_len, with_sentinel=self.sentinel)
            self.profiler.register("decode", lowered.compile())
        return self.profiler.snapshot()

    def _abort_inflight(self, status: str, err: RequestError) -> None:
        for req in self.queue:
            self._fail(req, status, err)
        self.queue = []
        for row, (b, req) in enumerate(self._pending):
            if not req.done:
                self._chunked_prefill.cancel_row(row)
                self._fail(req, status, err)
        if self._chunked_prefill.active:
            self._chunked_prefill.finish()
        self._pending = []
        for b, req in enumerate(self.live):
            if req is not None:
                self.live[b] = None
                self._fail(req, status, err)
