"""Serving runtime: prefill / decode step builders + a slot-based batch
engine (continuous-batching-lite).

``serve_step`` (the decode shape lowered by the dry-run) is one new token
against a KV/state cache of the workload's seq_len, exactly per the
assignment.  The engine keeps a fixed batch of slots; finished sequences
are replaced by newly prefilled prompts whose per-layer cache slices are
scattered into the batch cache.

Decode is the fused on-device loop (:func:`repro.models.lm.decode_tokens`):
each engine iteration advances every live slot by ``decode_block`` tokens
inside one compiled ``lax.scan`` — on-device argmax, a single
device->host transfer per block instead of one per token.  The cache
carries a per-slot ``pos`` vector, so slots admitted at different times
decode at their own offsets (no shared position counter).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.distributed.sharding import ShardingPlan
from repro.models.lm import (decode_tokens, init_lm_cache, lm_decode_step,
                             lm_forward, lm_prefill)


def make_prefill_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def prefill_step(params, inputs, cache):
        return lm_prefill(cfg, params, inputs, cache, kv_repeat=kv_repeat,
                          moe_groups=moe_groups)

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def decode_step(params, token, cache):
        return lm_decode_step(cfg, params, token, cache, kv_repeat=kv_repeat,
                              moe_groups=moe_groups)

    return decode_step


def make_decode_tokens(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    """Builder for the fused multi-token decode loop (jit with n static)."""
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def decode_n(params, cache, first_token, n: int):
        return decode_tokens(cfg, params, cache, first_token, n,
                             kv_repeat=kv_repeat, moe_groups=moe_groups)

    return decode_n


def make_encode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    """Encoder-only archs (hubert): one full forward is the serve step."""
    kv_repeat = plan.kv_repeat if plan else 1

    def encode_step(params, inputs):
        return lm_forward(cfg, params, inputs, kv_repeat=kv_repeat,
                          train=False)

    return encode_step


def greedy_generate(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
                    max_seq: int, gen_len: int,
                    plan: Optional[ShardingPlan] = None
                    ) -> Tuple[jax.Array, Any]:
    """Prefill + fused greedy decode: the whole generation burst runs as a
    single compiled program (no host round-trip per token)."""
    batch = next(iter(inputs.values())).shape[0]
    kv_repeat = plan.kv_repeat if plan else 1
    cache = init_lm_cache(cfg, batch, max_seq, kv_repeat=kv_repeat)
    prefill = jax.jit(make_prefill_step(cfg, plan))
    decode_n = jax.jit(make_decode_tokens(cfg, plan), static_argnames=("n",))
    logits, cache = prefill(params, inputs, cache)
    first = jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)
    if gen_len <= 1:
        return first, cache
    rest, cache = decode_n(params, cache, first, n=gen_len - 1)
    return jnp.concatenate([first, rest], axis=1), cache


# ---------------------------------------------------------------------------
# slot-based batch engine
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


def _scatter_group(batch_cache, src_cache, dst: jax.Array):
    """Insert every row of a batch-k prefill cache into slots ``dst`` ([k])
    of the batch cache in one call (per leaf the batch dim is axis 1:
    caches are stacked [n_rep, B, ...]).  Jitted by the engine so a whole
    admission group lands in a single dispatch instead of one full-cache
    copy per request."""
    def ins(full, one):
        if full.ndim == 0 or one is None:
            return full

        def body(i, acc):
            sl = jax.lax.dynamic_slice_in_dim(one, i, 1, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(
                acc, sl.astype(acc.dtype), dst[i], axis=1)

        return jax.lax.fori_loop(0, one.shape[1], body, full)
    segs = [jax.tree_util.tree_map(ins, fs, ss)
            for fs, ss in zip(batch_cache["segments"], src_cache["segments"])]
    return {"segments": segs, "pos": batch_cache["pos"]}


class ServingEngine:
    """Fixed-slot continuous batching over the fused decode loop.

    Each :meth:`step` admits queued prompts into free slots (batched
    same-length prefills into preallocated cache templates — no per-admission
    allocation), then decodes ``decode_block`` tokens for every slot in one
    compiled loop.  Per-slot ``pos`` means late-admitted slots attend only
    over their own valid cache rows.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_seq: int,
                 plan: Optional[ShardingPlan] = None, decode_block: int = 8):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.decode_block = decode_block
        kv_repeat = plan.kv_repeat if plan else 1
        self.cache = init_lm_cache(cfg, slots, max_seq, kv_repeat=kv_repeat)
        self._prefill = jax.jit(make_prefill_step(cfg, plan))
        self._decode_n = jax.jit(make_decode_tokens(cfg, plan),
                                 static_argnames=("n",))
        self._scatter = jax.jit(_scatter_group)
        self.kv_repeat = kv_repeat
        # preallocated prefill cache templates keyed by admission batch size
        # (prefill is functional, so one template serves every admission)
        self._templates: Dict[int, Any] = {}
        self.live: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots, 1), np.int32)
        self.pos = np.zeros((slots,), np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _template(self, batch: int):
        """Preallocated prefill cache templates.  Admission only ever uses
        batch sizes 1 and ``slots``, so at most two templates are built and
        both are reused for every subsequent admission."""
        if batch not in self._templates:
            self._templates[batch] = init_lm_cache(
                self.cfg, batch, self.max_seq, kv_repeat=self.kv_repeat)
        return self._templates[batch]

    def _admit(self) -> None:
        free = [b for b in range(self.slots) if self.live[b] is None]
        batch: List[Tuple[int, Request]] = []
        while free and self.queue:
            batch.append((free.pop(0), self.queue.pop(0)))
        if not batch:
            return
        # one batched prefill per prompt length (stale rows beyond the
        # prompt are masked by the per-slot pos, so templates need no reset)
        by_len: Dict[int, List[Tuple[int, Request]]] = {}
        for b, req in batch:
            by_len.setdefault(len(req.prompt), []).append((b, req))
        # bound XLA compiles to two prefill shapes per prompt length
        # (batch 1 and batch slots): intermediate group sizes admit singly
        groups: List[List[Tuple[int, Request]]] = []
        for group in by_len.values():
            if len(group) == self.slots:
                groups.append(group)
            else:
                groups.extend([m] for m in group)
        for group in groups:
            prompts = jnp.asarray(np.stack([req.prompt for _, req in group]))
            logits, one = self._prefill(self.params, {"tokens": prompts},
                                        self._template(len(group)))
            nxt = np.asarray(
                jnp.argmax(logits[:, -1, :self.cfg.vocab_size], -1), np.int32)
            dst = jnp.asarray([b for b, _ in group], jnp.int32)
            self.cache = self._scatter(self.cache, one, dst)
            for i, (b, req) in enumerate(group):
                req.out.append(int(nxt[i]))
                self.tokens[b, 0] = int(nxt[i])
                self.pos[b] = len(req.prompt)
                self.live[b] = req

    def step(self) -> int:
        """One engine iteration: admit, then decode a ``decode_block``-token
        burst for all slots on device. Returns number of live + queued."""
        self._admit()
        if not any(req is not None for req in self.live):
            return 0
        kblk = self.decode_block
        self.cache = dict(self.cache, pos=jnp.asarray(self.pos, jnp.int32))
        toks, self.cache = self._decode_n(self.params, self.cache,
                                          jnp.asarray(self.tokens), n=kblk)
        toks = np.asarray(toks)                     # one host sync per block
        n_live = 0
        for b, req in enumerate(self.live):
            if req is None:
                continue
            room = min(req.max_new - len(req.out),
                       self.max_seq - 1 - int(self.pos[b]))
            take = min(kblk, max(room, 0))
            req.out.extend(int(t) for t in toks[b, :take])
            if take:
                self.tokens[b, 0] = int(toks[b, take - 1])
            self.pos[b] += take
            if len(req.out) >= req.max_new or self.pos[b] >= self.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.live[b] = None
            else:
                n_live += 1
        return n_live + len(self.queue)

    def run(self) -> List[Request]:
        while self.step() or self.queue:
            pass
        return self.finished
