"""Serving runtime: prefill / decode step builders + a slot-based batch
engine (continuous-batching-lite).

``serve_step`` (the decode shape lowered by the dry-run) is one new token
against a KV/state cache of the workload's seq_len, exactly per the
assignment.  The engine keeps a fixed batch of slots; finished sequences
are replaced by newly prefied prompts whose per-layer cache slices are
scattered into the batch cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.distributed.sharding import ShardingPlan
from repro.models.lm import (init_lm_cache, lm_decode_step, lm_forward,
                             lm_prefill)


def make_prefill_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def prefill_step(params, inputs, cache):
        return lm_prefill(cfg, params, inputs, cache, kv_repeat=kv_repeat,
                          moe_groups=moe_groups)

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def decode_step(params, token, cache):
        return lm_decode_step(cfg, params, token, cache, kv_repeat=kv_repeat,
                              moe_groups=moe_groups)

    return decode_step


def make_encode_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    """Encoder-only archs (hubert): one full forward is the serve step."""
    kv_repeat = plan.kv_repeat if plan else 1

    def encode_step(params, inputs):
        return lm_forward(cfg, params, inputs, kv_repeat=kv_repeat,
                          train=False)

    return encode_step


def greedy_generate(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
                    max_seq: int, gen_len: int,
                    plan: Optional[ShardingPlan] = None
                    ) -> Tuple[jax.Array, Any]:
    """Prefill + greedy decode loop (used by examples/tests)."""
    batch = next(iter(inputs.values())).shape[0]
    kv_repeat = plan.kv_repeat if plan else 1
    cache = init_lm_cache(cfg, batch, max_seq, kv_repeat=kv_repeat)
    prefill = jax.jit(make_prefill_step(cfg, plan))
    decode = jax.jit(make_decode_step(cfg, plan))
    logits, cache = prefill(params, inputs, cache)
    toks = [jnp.argmax(logits[..., :cfg.vocab_size], -1).astype(jnp.int32)]
    for _ in range(gen_len - 1):
        logits, cache = decode(params, toks[-1], cache)
        toks.append(jnp.argmax(logits[..., :cfg.vocab_size], -1)
                    .astype(jnp.int32))
    return jnp.concatenate(toks, axis=1), cache


# ---------------------------------------------------------------------------
# slot-based batch engine
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


def _scatter_slot(batch_cache, slot_cache, b: int):
    """Insert a batch-1 cache into slot b of the batch cache (per leaf the
    batch dim is axis 1: caches are stacked [n_rep, B, ...])."""
    def ins(full, one):
        if full.ndim == 0 or one is None:
            return full
        return jax.lax.dynamic_update_slice_in_dim(full, one.astype(full.dtype),
                                                   b, axis=1)
    segs = [jax.tree_util.tree_map(ins, fs, ss)
            for fs, ss in zip(batch_cache["segments"], slot_cache["segments"])]
    return {"segments": segs, "pos": batch_cache["pos"]}


class ServingEngine:
    """Fixed-slot continuous batching. Decode advances all live slots each
    step; finished slots are refilled from the queue via single-sequence
    prefill + cache scatter."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_seq: int,
                 plan: Optional[ShardingPlan] = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        kv_repeat = plan.kv_repeat if plan else 1
        self.cache = init_lm_cache(cfg, slots, max_seq, kv_repeat=kv_repeat)
        self._prefill1 = jax.jit(make_prefill_step(cfg, plan))
        self._decode = jax.jit(make_decode_step(cfg, plan))
        self.kv_repeat = kv_repeat
        self.live: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots, 1), np.int32)
        self.pos = np.zeros((slots,), np.int64)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for b in range(self.slots):
            if self.live[b] is None and self.queue:
                req = self.queue.pop(0)
                one = init_lm_cache(self.cfg, 1, self.max_seq,
                                    kv_repeat=self.kv_repeat)
                logits, one = self._prefill1(
                    self.params, {"tokens": jnp.asarray(req.prompt[None])},
                    one)
                self.cache = _scatter_slot(self.cache, one, b)
                tok = int(jnp.argmax(logits[0, -1, :self.cfg.vocab_size]))
                req.out.append(tok)
                self.tokens[b, 0] = tok
                self.pos[b] = len(req.prompt)
                self.live[b] = req

    def step(self) -> int:
        """One engine iteration. Returns number of live sequences."""
        self._admit()
        if not any(self.live):
            return 0
        # NOTE: single shared pos counter in the cache; slots admitted later
        # waste a few cache rows — acceptable for the example engine.
        self.cache = dict(self.cache, pos=jnp.asarray(
            int(self.pos.max()), jnp.int32))
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(self.tokens), self.cache)
        nxt = np.asarray(jnp.argmax(
            logits[:, 0, :self.cfg.vocab_size], -1), np.int32)
        n_live = 0
        for b, req in enumerate(self.live):
            if req is None:
                continue
            req.out.append(int(nxt[b]))
            self.tokens[b, 0] = int(nxt[b])
            self.pos[b] += 1
            if len(req.out) >= req.max_new or self.pos[b] >= self.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.live[b] = None
            else:
                n_live += 1
        return n_live + len(self.queue)

    def run(self) -> List[Request]:
        while self.step() or self.queue:
            pass
        return self.finished
