"""Chunked-prefill subsystem: state-carrying long-context prefill.

The paper's long-context regime (TTFT inversion around ~57K tokens) makes
monolithic prefill the serving bottleneck: one O(L) forward spikes
activation memory at exactly the sequence lengths under study and stalls
every decoding slot behind it (head-of-line blocking).  This module
splits prompts into fixed-size chunks and drives them through the single
compiled :func:`repro.models.lm.lm_prefill_chunk` step, which carries
state between chunks — attention layers scatter KV at each row's running
offset with an offset causal mask, rolling sliding-window layers fold the
chunk into their ring-buffer caches with a modular mask (no rolled copy),
mamba1/mamba2 layers carry their conv + SSM states — so a 57K-token
prompt prefills in 1K-token chunks with flat peak memory and chunk-parity
with one-shot prefill.  Every decodable architecture family — dense,
windowed ("local"), SSM, hybrid, windowed-hybrid — admits through this
one path; there is no separate one-shot fallback pipeline.

Chunk/decode interleave contract (what ``ServingEngine`` relies on):

* ``ChunkedPrefill`` owns an in-flight *group*: a padded mixed-length
  batch of prompts plus a group cache.  One :meth:`ChunkedPrefill.step`
  call advances the whole group by exactly ONE chunk and returns
  immediately, so the engine can interleave one prefill chunk with one
  ``decode_block`` burst per iteration — decode makes progress on every
  engine iteration even while a long prompt is prefilling.
* Rows are *emitted* (first token + filled cache rows, ready to scatter
  into decode slots) as soon as their own prompt completes, not when the
  whole group does: short prompts sharing a group with a long one start
  decoding after their last chunk, chunks earlier than the long row's.
* Heterogeneous prompt lengths need no same-length grouping: prompts are
  right-padded onto the chunk grid and a per-row ``lengths`` vector makes
  padding inert (no SSM-state updates; stale KV is overwritten or masked
  by the decode-time valid_len, and ring-buffer caches gate their writes
  on the valid length so padding never clobbers live window history).
  Rows past the real group (batch padded to a template size) are
  zero-length and therefore complete no-ops.
* The group cache template is allocated once per retained batch size and
  reused for every subsequent group (prefill is functional — the template
  itself is never mutated).

Compiled-shape discipline: every chunk step lowers to the same
``[batch, chunk]`` program regardless of prompt length, so XLA compiles
at most one prefill program per retained batch size (times the KV bucket
rungs actually touched — a ladder that tops out at the model's largest
KV extent, i.e. the *window* for rolling architectures) and peak
activation memory is O(chunk), not O(prompt).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.distributed.sharding import ShardingPlan
from repro.kernels import dispatch as kdispatch
from repro.models.lm import init_lm_cache, lm_prefill_chunk
from repro.serving.bucketing import (clamped_bucket, kv_cache_extent,
                                     rope_len_for)


def _has_attn_cache(cfg: ModelConfig) -> bool:
    """Only architectures with attention layers hold KV caches worth
    bucketing; pure-SSM stacks would pay a compile per rung for nothing."""
    return cfg.attn is not None or cfg.shared_attn is not None


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill needs causal attention with a state-carrying cache.

    Rolling sliding-window ("local") layers qualify: their ring-buffer
    caches carry the trailing window between chunks (modular scatter +
    ring-unrolling mask).  Excluded: encoder layers (bidirectional —
    every token sees the whole sequence, so there is no prefix-extension
    recurrence) and audio frontends (the serving path feeds token chunks;
    audio models embed precomputed frame features instead).  Vision
    frontends pass — token-only serving treats them as dense decoders.
    """
    if cfg.frontend == "audio":
        return False
    return "encoder" not in cfg.layer_kinds


def _make_chunk_step(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def chunk_step(params, tokens, lengths, cache, kv_bucket=None,
                   rope_len=None, with_sentinel=False):
        return lm_prefill_chunk(cfg, params, {"tokens": tokens}, cache,
                                lengths=lengths, kv_repeat=kv_repeat,
                                moe_groups=moe_groups, kv_bucket=kv_bucket,
                                rope_len=rope_len,
                                with_sentinel=with_sentinel)

    return chunk_step


# jitted chunk steps keyed by everything the closure actually depends on
# (cfg plus the plan's kv_repeat/moe_groups, plus the REPRO_RING_BUCKETS
# flag — it is read at TRACE time inside lm_prefill_chunk, so it must key
# the cache or flipping the env after a first compile would silently
# reuse the old trace): repeated chunked_prefill calls must reuse the
# compiled program, not re-trace.  kv_bucket and rope_len are static
# arguments: one compile per bucket-ladder rung actually touched
# (rope_len is constant per serving deployment).
_STEP_CACHE: Dict[Tuple[ModelConfig, int, int, bool], Any] = {}


def _jitted_chunk_step(cfg: ModelConfig, plan: Optional[ShardingPlan]):
    key = (cfg, plan.kv_repeat if plan else 1,
           plan.moe_groups if plan else 1, kdispatch.ring_buckets())
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = jax.jit(_make_chunk_step(cfg, plan),
                                   static_argnames=("kv_bucket", "rope_len",
                                                    "with_sentinel"))
    return _STEP_CACHE[key]


def chunk_schedule(lens: np.ndarray, chunk: int,
                   idx: int) -> Tuple[int, np.ndarray, np.ndarray]:
    """Per-chunk admission arithmetic, shared by the group scheduler and
    the host-loop helper so ragged-last-chunk / finish detection can never
    diverge between them.  Returns ``(offset, valid_lens, finished)`` for
    chunk ``idx``: how many of the chunk's tokens are valid per row, and
    which rows' prompts end inside this chunk."""
    off = idx * chunk
    clens = np.clip(lens - off, 0, chunk).astype(np.int32)
    fin = (lens > off) & (lens <= off + chunk)
    return off, clens, fin


def _cache_kv_extent(cache) -> Optional[int]:
    """KV row capacity of a cache pytree (max Skv across "k"/"v" leaves,
    stacked [n_rep, B, Skv, KV, hd]); None when no layer holds a KV cache.
    Uses the same leaf predicate the models layer slices with, so the
    selected bucket always bounds exactly the leaves that get sliced."""
    from repro.models.lm import _is_kv_leaf
    best = None
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache):
        if _is_kv_leaf(path):
            best = max(best or 0, int(leaf.shape[2]))
    return best


def chunked_prefill(cfg: ModelConfig, params, tokens: jax.Array, cache, *,
                    chunk_size: int, lengths: Optional[Sequence[int]] = None,
                    plan: Optional[ShardingPlan] = None,
                    step=None, kv_buckets: bool = True,
                    rope_len: Optional[int] = None
                    ) -> Tuple[jax.Array, Any]:
    """Prefill ``tokens`` [B, S] (right-padded, per-row valid ``lengths``)
    in ``chunk_size`` chunks.  Drop-in replacement for
    :func:`repro.models.lm.lm_prefill` — returns (last-valid-token logits
    [B, 1, V], filled cache) — but runs the fixed-shape chunk program
    ceil(S/chunk) times instead of one O(S) program.

    ``kv_buckets`` (default on, also gated by ``REPRO_PREFILL_KV_BUCKETS``)
    bounds each chunk's attention to the live prefix: chunk ``i`` runs
    with a static KV bucket covering ``(i+1) * chunk`` rows (smallest
    power-of-two rung, capped at the model's KV extent — the *window* for
    rolling architectures), so early chunks pay early-prefix FLOPs/IO
    instead of the full extent.  Outputs are bit-identical either way.

    ``rope_len`` sizes the rope tables; it defaults to the prompt length
    when that outgrows the cache extent (rolling windows), so positions
    past the window still rotate correctly.

    ``step`` overrides the compiled chunk callable (e.g. an AOT-compiled
    executable, so benchmarks don't pay a second trace+compile); bucketing
    and rope sizing are disabled then — the executable's shapes and tables
    are fixed by its caller.
    """
    tokens = jnp.asarray(tokens)
    b, total = tokens.shape
    lens = (np.full((b,), total, np.int64) if lengths is None
            else np.asarray(lengths, np.int64))
    kv_extent = None
    aot = step is not None
    if not aot:
        step = _jitted_chunk_step(cfg, plan)
        if (kv_buckets and kdispatch.prefill_kv_buckets()
                and supports_chunked_prefill(cfg) and _has_attn_cache(cfg)):
            kv_extent = _cache_kv_extent(cache)
        if rope_len is None and _has_attn_cache(cfg):
            ext = _cache_kv_extent(cache)
            if ext is not None and ext < total:
                # rope_len is STATIC on the jitted step: round the prompt
                # length up to a power of two so nearby lengths share one
                # compiled program (values at a position are identical for
                # any sufficient table size)
                rope_len = max(ext, 1 << (total - 1).bit_length())
    n_chunks = max(1, -(-total // chunk_size))
    pad = n_chunks * chunk_size - total
    if pad:
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)))
    logits = None
    for i in range(n_chunks):
        off, clens, fin = chunk_schedule(lens, chunk_size, i)
        if aot:
            lg, cache = step(params, tokens[:, off:off + chunk_size],
                             jnp.asarray(clens), cache)
        else:
            bucket = clamped_bucket(off + chunk_size, kv_extent)
            lg, cache = step(params, tokens[:, off:off + chunk_size],
                             jnp.asarray(clens), cache, kv_bucket=bucket,
                             rope_len=rope_len)
        if logits is None:
            logits = lg
        elif fin.any():
            logits = jnp.where(jnp.asarray(fin)[:, None, None], lg, logits)
    return logits, cache


class ChunkedPrefill:
    """Incremental chunked-prefill scheduler for the serving engine.

    One group at a time; :meth:`step` advances it by one chunk and reports
    rows whose prompt just completed (see module docstring for the full
    interleave contract).

    ``sentinel`` (default on) folds the per-row finiteness sentinel of
    :func:`lm_prefill_chunk` into every chunk: rows that turn non-finite
    are quarantined — their remaining chunks go inert, they never emit —
    and reported to the engine, which fails the request with
    ``DivergenceDetected`` while co-batched rows prefill on untouched.
    ``fault_plan`` (a :class:`repro.serving.fault_inject.FaultPlan`)
    optionally injects NaN into exact (chunk, row) points for testing."""

    def __init__(self, cfg: ModelConfig, params, *, max_seq: int,
                 chunk_size: int = 256,
                 plan: Optional[ShardingPlan] = None,
                 sentinel: bool = True, fault_plan=None, metrics=None):
        if not supports_chunked_prefill(cfg):
            raise ValueError(f"{cfg.name}: architecture does not support "
                             "chunked prefill")
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.chunk = int(chunk_size)
        self.sentinel = bool(sentinel)
        self._faults = fault_plan
        self.kv_repeat = plan.kv_repeat if plan else 1
        # bucket ladder top: the model's largest KV extent — max_seq for
        # append-only caches, the window for rolling ones (O(log window)
        # compiles however long the prompt grows)
        self.kv_extent = kv_cache_extent(cfg, max_seq)
        self.kv_buckets = self.kv_extent is not None
        # rolling caches span only their window: rope must cover max_seq
        self.rope_len = rope_len_for(cfg, max_seq)
        self._step = _jitted_chunk_step(cfg, plan)
        self._templates: Dict[int, Any] = {}
        self._group: Optional[Dict[str, Any]] = None
        # (batch, kv_bucket) combos this scheduler has dispatched: the
        # first dispatch of a combo pays trace+compile, and the engine's
        # latency model must segregate that sample from steady state.
        # (The jitted step cache is process-global, so a second scheduler
        # instance may tag an already-compiled combo "fresh" — that only
        # diverts one sample to the compile record, never poisons steady.)
        self._dispatched: set = set()
        # facts about the most recent step(), for the engine's telemetry:
        # {"bucket", "valid_tokens", "valid_per_row", "class_tokens",
        #  "fresh_compile"}
        self.last_chunk: Optional[Dict[str, Any]] = None
        # optional shared MetricsRegistry (the engine passes its own)
        self._m_chunks = self._m_quar = self._m_rows = None
        if metrics is not None:
            self._m_chunks = metrics.counter(
                "repro_prefill_chunks_total", "prefill chunks dispatched")
            self._m_quar = metrics.counter(
                "repro_prefill_rows_quarantined_total",
                "group rows removed by the prefill divergence sentinel")
            self._m_rows = metrics.gauge(
                "repro_prefill_group_rows",
                "rows still prefilling in the in-flight group")

    @property
    def active(self) -> bool:
        return self._group is not None

    @property
    def group_cache(self):
        """The in-flight group's cache (scatter emitted rows from here)."""
        assert self._group is not None
        return self._group["cache"]

    def _template(self, batch: int):
        if batch not in self._templates:
            self._templates[batch] = init_lm_cache(
                self.cfg, batch, self.max_seq, kv_repeat=self.kv_repeat)
        return self._templates[batch]

    def start(self, prompts: List[np.ndarray],
              batch: Optional[int] = None,
              priorities: Optional[Sequence[int]] = None) -> None:
        """Begin a group over mixed-length ``prompts`` (1-D int arrays).
        ``batch`` pads the compiled batch dimension (rows past
        ``len(prompts)`` get zero-length prompts and are inert), bounding
        XLA compiles to one chunk program per retained batch size.

        ``prompts`` arrive in SCHEDULER order — the engine's admission
        policy decides group membership and row order; this class only
        executes the group.  ``priorities`` (parallel to ``prompts``;
        default all class 0) labels each row's priority class so
        :attr:`last_chunk` can report per-class valid-token counts — the
        DRR accounting and fairness benches read them without walking
        engine internals."""
        assert self._group is None, "one prefill group at a time"
        k = len(prompts)
        kb = batch or k
        assert kb >= k
        lens = np.zeros((kb,), np.int64)
        lens[:k] = [len(p) for p in prompts]
        if lens.max() > self.max_seq:
            raise ValueError(f"prompt length {int(lens.max())} exceeds "
                             f"max_seq {self.max_seq}")
        prios = np.zeros((kb,), np.int64)
        if priorities is not None:
            assert len(priorities) == k
            prios[:k] = np.asarray(priorities, np.int64)
        n_chunks = max(1, -(-int(lens.max()) // self.chunk))
        toks = np.zeros((kb, n_chunks * self.chunk), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = np.asarray(p, np.int32)
        self._group = {"tokens": toks, "lens": lens, "n_chunks": n_chunks,
                       "idx": 0, "k": k, "emitted": np.zeros(kb, bool),
                       "bad": np.zeros(kb, bool), "priorities": prios,
                       "cache": self._template(kb)}

    def cancel_row(self, row: int) -> None:
        """Withdraw one group row (deadline expiry / engine quarantine):
        its remaining chunks go inert (zero valid tokens) and it will
        never emit.  Other rows are untouched; the group keeps running to
        its original chunk count."""
        g = self._group
        if g is None or not (0 <= row < g["lens"].shape[0]):
            return
        g["lens"][row] = 0
        g["emitted"][row] = True

    def step(self) -> Tuple[List[Tuple[int, int, int]], bool, List[int]]:
        """Run ONE chunk for the in-flight group.

        Returns ``(emitted, done, diverged)``: ``emitted`` lists
        ``(row, first_token, prompt_len)`` for rows whose prompt completed
        this chunk (their cache rows in :attr:`group_cache` are final and
        ready to scatter); ``done`` is True once every chunk has run —
        call :meth:`finish` afterwards; ``diverged`` lists rows whose
        sentinel tripped THIS chunk (already quarantined via
        :meth:`cancel_row` semantics — the engine owns failing their
        requests)."""
        g = self._group
        assert g is not None
        if self._faults is not None and self._faults.active:
            from repro.serving.fault_inject import poison_slot
            for r in self._faults.nan_prefill_rows(g["idx"]):
                if 0 <= r < g["lens"].shape[0]:
                    g["cache"] = poison_slot(g["cache"], r)
        off, clens, fin = chunk_schedule(g["lens"], self.chunk, g["idx"])
        ctoks = jnp.asarray(g["tokens"][:, off:off + self.chunk])
        # every row's pos <= off, so a bucket covering off + chunk (capped
        # at the extent ladder's top) bounds all of this chunk's KV reads
        # and writes to the live prefix
        kv_bucket = (clamped_bucket(off + self.chunk, self.kv_extent)
                     if self.kv_buckets and kdispatch.prefill_kv_buckets()
                     else None)
        combo = (g["lens"].shape[0], kv_bucket)
        class_tokens: Dict[int, int] = {}
        for r in range(g["k"]):
            if clens[r]:
                cls = int(g["priorities"][r])
                class_tokens[cls] = class_tokens.get(cls, 0) + int(clens[r])
        self.last_chunk = {"bucket": kv_bucket,
                           "valid_tokens": int(clens.sum()),
                           "valid_per_row": np.asarray(clens),
                           "class_tokens": class_tokens,
                           "fresh_compile": combo not in self._dispatched}
        self._dispatched.add(combo)
        if self._m_chunks is not None:
            self._m_chunks.inc()
        out = self._step(self.params, ctoks, jnp.asarray(clens), g["cache"],
                         kv_bucket=kv_bucket, rope_len=self.rope_len,
                         with_sentinel=self.sentinel)
        diverged: List[int] = []
        if self.sentinel:
            logits, g["cache"], ok = out
            # one [B]-bool host read per CHUNK (not per token); rows past
            # the real group and rows already done are vacuously finite
            bad = ~np.asarray(ok) & ~g["bad"] & ~g["emitted"] & (clens > 0)
            bad[g["k"]:] = False
            if bad.any():
                g["bad"] |= bad
                for r in np.nonzero(bad)[0]:
                    diverged.append(int(r))
                    self.cancel_row(int(r))
                if self._m_quar is not None:
                    self._m_quar.inc(len(diverged))
        else:
            logits, g["cache"] = out
        g["idx"] += 1
        fin &= ~g["emitted"]
        fin[g["k"]:] = False
        emitted: List[Tuple[int, int, int]] = []
        if fin.any():
            nxt = np.asarray(jnp.argmax(
                logits[:, -1, :self.cfg.vocab_size], -1), np.int32)
            emitted = [(int(r), int(nxt[r]), int(g["lens"][r]))
                       for r in np.nonzero(fin)[0]]
            g["emitted"] |= fin
        if self._m_rows is not None:
            self._m_rows.set(int((~g["emitted"][:g["k"]]).sum()))
        return emitted, g["idx"] >= g["n_chunks"], diverged

    def finish(self) -> None:
        """Retire the completed group (template is reused by the next)."""
        self._group = None
        if self._m_rows is not None:
            self._m_rows.set(0)
