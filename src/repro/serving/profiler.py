"""Measured operator-level profiling: per-dispatch device-time attribution
to kernel families via ``jax.profiler`` trace capture, with a cheap
coarse fallback for hosts without trace support.

The paper's headline numbers are *measured*: selective-scan kernels
account for >55% of edge-inference latency, and the Transformer/SSM
crossover is a wall-clock phenomenon.  ``operator_costs`` (PR 7) only
gives the *static* flop/byte walk — this module supplies the measured
counterpart:

* **trace mode** (``REPRO_PROFILE=trace``): wrap a window of dispatches
  in ``jax.profiler.trace``, parse the resulting Chrome-trace JSON
  (``*.trace.json.gz``), and attribute every device event back to a
  kernel family.  The key observation (verified on this container's
  jax/XLA): trace event names are exactly the compiled HLO op names
  (``bitcast_dot_fusion.2``, ``dot.16``, ...), and re-lowering the same
  jit computation reproduces them — so a family map built from
  ``compiled.as_text()`` with the SAME classifier ``operator_costs``
  uses (:meth:`repro.core.hlo_analysis.HloAnalyzer._classify`, i.e. the
  gemm/ssm/norm/memory/arith/collective taxonomy driven by
  ``named_scope`` metadata) attributes measured device time without
  touching the engine's cached executables.  Container ops (``while`` /
  ``call`` / ``conditional``) emit trace events spanning their whole
  body — they are excluded from attribution or interiors would be
  double-counted.  Only threads that executed at least one known op are
  scanned, so host-side python/runtime events never pollute the
  ``unattributed`` residual.
* **coarse mode** (``REPRO_PROFILE=coarse``): the engine's existing
  block-until-ready sub-dispatch wall timings are accumulated per
  program key (one dict add per dispatch — measured bookkeeping
  self-time is tracked in :attr:`Profiler.overhead_ms` and smoke-gated
  < 3% of decode wall) and apportioned across families at snapshot time
  by each program's *static* roofline weights.  Shares still sum to 1;
  they are model-weighted rather than measured, which is exactly the
  degradation an edge/CI host without trace support should get.
* **off** (default): every hook is a no-op.

Snapshot records carry ``version`` + ``mode`` so downstream readers
(fig7/fig8 measured curves, ``BENCH_decode.json``) can reject stale
files and distinguish measured from degraded shares.
"""
from __future__ import annotations

import contextlib
import glob
import gzip
import json
import os
import shutil
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.core.hlo_analysis import HloAnalyzer

#: schema version stamped on profiler snapshots / measured-share records
PROFILE_SCHEMA_VERSION = 1

PROFILE_MODES = ("off", "coarse", "trace")

#: ops whose trace events span their whole body — attributing them would
#: double count every interior kernel
_CONTAINER_OPS = ("while", "call", "conditional")
_CONTAINER = "__container__"

#: nominal roofline peaks for coarse-mode static weights; only the
#: *ratios* between families matter, never the absolute throughput
_PEAK_FLOPS = 1.0e12
_PEAK_BYTES = 1.0e11


def family_map(hlo_text: str) -> Dict[str, str]:
    """``{op_name: family}`` over every op in every computation of an
    optimized-HLO dump, using the same classifier ``operator_costs``
    uses.  Container ops map to a sentinel so the trace parser can skip
    them without counting them as unattributed."""
    analyzer = HloAnalyzer(hlo_text)
    out: Dict[str, str] = {}
    for comp, ops in analyzer.comps.items():
        if comp == "__entry__":      # alias of the entry computation
            continue
        for op in ops:
            out[op.name] = (_CONTAINER if op.opcode in _CONTAINER_OPS
                            else analyzer._classify(op))
    return out


def static_family_weights(hlo_text: str) -> Dict[str, float]:
    """Normalized per-family share of modeled runtime (roofline
    ``max(flops/peak, bytes/peak)`` per kernel, trip-count corrected) —
    the apportioning vector coarse mode uses."""
    summary = HloAnalyzer(hlo_text).summarize()
    t: Dict[str, float] = {}
    for k in summary.kernels:
        cost = max(k.flops / _PEAK_FLOPS, k.bytes / _PEAK_BYTES) * k.count
        t[k.clazz] = t.get(k.clazz, 0.0) + cost
    total = sum(t.values())
    if total <= 0:
        return {}
    return {fam: v / total for fam, v in sorted(t.items())}


@dataclass
class FamilyTimes:
    """Attributed device time for one profiling window (ms per family)."""

    key: str = ""
    ms: Dict[str, float] = field(default_factory=dict)
    unattributed_ms: float = 0.0
    wall_ms: float = 0.0
    events: int = 0
    mode: str = "off"
    degraded: bool = False      # trace mode fell back to static weights

    def add(self, family: str, ms: float) -> None:
        self.ms[family] = self.ms.get(family, 0.0) + ms

    def merge(self, other: "FamilyTimes") -> None:
        for fam, v in other.ms.items():
            self.add(fam, v)
        self.unattributed_ms += other.unattributed_ms
        self.wall_ms += other.wall_ms
        self.events += other.events
        self.mode = other.mode
        self.degraded = self.degraded or other.degraded

    def shares(self) -> Dict[str, float]:
        """Per-family share of *attributed* device time (sums to 1 when
        any time was attributed)."""
        total = sum(self.ms.values())
        if total <= 0:
            return {}
        return {fam: v / total for fam, v in sorted(self.ms.items())}

    def as_dict(self) -> Dict[str, Any]:
        return {"key": self.key, "mode": self.mode,
                "degraded": self.degraded, "events": self.events,
                "wall_ms": self.wall_ms,
                "unattributed_ms": self.unattributed_ms,
                "ms": dict(sorted(self.ms.items())),
                "shares": self.shares()}


def parse_trace_dir(trace_dir: str, fam_map: Dict[str, str]
                    ) -> FamilyTimes:
    """Attribute every device event in a ``jax.profiler.trace`` output
    directory (Chrome-trace ``*.trace.json.gz``) to a kernel family.

    Two-pass per file: first find the threads that executed at least one
    known op (device executor threads), then accumulate only events from
    those threads — host-side python/runtime threads never reach the
    ``unattributed`` residual.  Durations are trace microseconds,
    converted to ms."""
    res = FamilyTimes()
    paths = sorted(glob.glob(os.path.join(trace_dir, "**",
                                          "*.trace.json.gz"),
                             recursive=True))
    for path in paths:
        try:
            with gzip.open(path, "rt") as f:
                events = json.load(f).get("traceEvents", [])
        except (OSError, ValueError):
            continue
        device_tids = set()
        for e in events:
            if (e.get("ph") == "X" and "dur" in e
                    and e.get("name") in fam_map):
                device_tids.add((e.get("pid"), e.get("tid")))
        for e in events:
            if e.get("ph") != "X" or "dur" not in e:
                continue
            if (e.get("pid"), e.get("tid")) not in device_tids:
                continue
            fam = fam_map.get(e.get("name"))
            if fam == _CONTAINER:
                continue
            ms = float(e["dur"]) / 1e3
            if fam is None:
                res.unattributed_ms += ms
            else:
                res.add(fam, ms)
                res.events += 1
    return res


@dataclass
class _Program:
    fam_map: Dict[str, str]
    weights: Dict[str, float]


class Profiler:
    """Per-dispatch device-time attribution hub for one engine or bench.

    ``mode`` defaults to the ``REPRO_PROFILE`` env var (read once at
    construction).  ``register(key, compiled)`` teaches the profiler one
    compiled program's op-name → family map and static weight vector;
    :meth:`window` wraps a group of dispatches and attributes their
    device time; :meth:`observe` is the always-cheap per-dispatch hook
    the engine calls with its existing block-until-ready wall timings.
    """

    def __init__(self, mode: Optional[str] = None,
                 clock: Optional[Callable[[], float]] = None):
        mode = (mode if mode is not None
                else os.environ.get("REPRO_PROFILE", "off") or "off")
        if mode not in PROFILE_MODES:
            raise ValueError(f"REPRO_PROFILE={mode!r}: expected one of "
                             f"{PROFILE_MODES}")
        self.mode = mode
        # time.monotonic, like every serving module: the injectable-clock
        # contract (scripts/check_clock.py) keeps fake-clock tests able to
        # drive ALL serving time from one base
        self._clock = clock or time.monotonic
        self._programs: Dict[str, _Program] = {}
        self._merged_map: Dict[str, str] = {}
        self._totals: Dict[str, FamilyTimes] = {}
        self._coarse_wall: Dict[str, float] = {}
        self._coarse_n: Dict[str, int] = {}
        #: measured profiler bookkeeping self-time (ms) — the coarse-mode
        #: overhead the verify gate bounds at < 3% of decode wall
        self.overhead_ms = 0.0
        self._tracing = False

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

    def register(self, key: str, compiled: Any) -> None:
        """Register one compiled program (or its optimized-HLO text)
        under ``key``.  Idempotent per key."""
        if key in self._programs:
            return
        text = compiled if isinstance(compiled, str) else compiled.as_text()
        fmap = family_map(text)
        self._programs[key] = _Program(
            fam_map=fmap, weights=static_family_weights(text))
        for name, fam in fmap.items():
            # identical names across programs keep the first family seen;
            # family-level collisions across re-lowers are benign
            self._merged_map.setdefault(name, fam)

    def registered(self, key: str) -> bool:
        return key in self._programs

    # ------------------------------------------------------------ windows
    @contextlib.contextmanager
    def window(self, key: str):
        """Profile every dispatch inside the ``with`` body and attribute
        its device time; yields a :class:`FamilyTimes` filled on exit.
        Off mode yields an empty record; coarse mode wall-times the
        window and apportions by the key's static weights; trace mode
        captures and parses a real profiler trace (degrading to the
        coarse apportioning, flagged, when the host produced no usable
        trace)."""
        res = FamilyTimes(key=key, mode=self.mode)
        if self.mode == "off" or self._tracing:
            yield res
            return
        if self.mode == "coarse":
            t0 = self._clock()
            try:
                yield res
            finally:
                t1 = self._clock()
                res.wall_ms = (t1 - t0) * 1e3
                self._apportion(key, res.wall_ms, res)
                self._merge_total(key, res)
                self.overhead_ms += (self._clock() - t1) * 1e3
            return
        # trace mode
        import jax
        tmp = tempfile.mkdtemp(prefix="repro_profile_")
        self._tracing = True
        tb0 = self._clock()
        jax.profiler.start_trace(tmp)
        t0 = self._clock()
        self.overhead_ms += (t0 - tb0) * 1e3
        try:
            yield res
        finally:
            t1 = self._clock()
            try:
                jax.profiler.stop_trace()
                fam_map = (self._programs[key].fam_map
                           if key in self._programs else self._merged_map)
                parsed = parse_trace_dir(tmp, fam_map)
                if parsed.events == 0:
                    # no usable device trace on this host: degrade to the
                    # coarse static apportioning so shares still exist
                    res.degraded = True
                    self._apportion(key, (t1 - t0) * 1e3, res)
                else:
                    res.ms = parsed.ms
                    res.unattributed_ms = parsed.unattributed_ms
                    res.events = parsed.events
            finally:
                self._tracing = False
                shutil.rmtree(tmp, ignore_errors=True)
            res.wall_ms = (t1 - t0) * 1e3
            self._merge_total(key, res)
            self.overhead_ms += (self._clock() - t1) * 1e3

    def _apportion(self, key: str, wall_ms: float, res: FamilyTimes) -> None:
        prog = self._programs.get(key)
        if prog is None or not prog.weights:
            res.unattributed_ms += wall_ms
            return
        for fam, w in prog.weights.items():
            res.add(fam, wall_ms * w)

    def _merge_total(self, key: str, res: FamilyTimes) -> None:
        tot = self._totals.get(key)
        if tot is None:
            self._totals[key] = tot = FamilyTimes(key=key, mode=self.mode)
        tot.merge(res)

    # ---------------------------------------------------------- coarse hook
    def observe(self, key: str, wall_ms: float) -> None:
        """Always-cheap per-dispatch hook: accumulate one blocked-on
        wall-time sample under ``key`` (one dict add; apportioned by
        static weights at snapshot time).  No-op when off."""
        if self.mode == "off":
            return
        t0 = self._clock()
        self._coarse_wall[key] = self._coarse_wall.get(key, 0.0) + wall_ms
        self._coarse_n[key] = self._coarse_n.get(key, 0) + 1
        self.overhead_ms += (self._clock() - t0) * 1e3

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, Any]:
        """JSON-able state: per-key windowed attributions plus the
        coarse per-dispatch accumulations apportioned by static
        weights."""
        coarse: Dict[str, Any] = {}
        for key, wall in sorted(self._coarse_wall.items()):
            res = FamilyTimes(key=key, mode="coarse")
            self._apportion(key, wall, res)
            res.wall_ms = wall
            coarse[key] = res.as_dict()
            coarse[key]["dispatches"] = self._coarse_n.get(key, 0)
        return {"version": PROFILE_SCHEMA_VERSION, "mode": self.mode,
                "overhead_ms": self.overhead_ms,
                "windows": {k: t.as_dict()
                            for k, t in sorted(self._totals.items())},
                "coarse": coarse}
