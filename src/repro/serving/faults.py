"""Failure taxonomy + request terminal states for the serving layer.

The paper's serving regime — multi-minute 57K-token requests on
memory-constrained edge devices — makes silent corruption the dominant
failure mode: one NaN burst or one bad preemption blob poisons a whole
continuous-batching group unless the engine can name the failure,
quarantine the request, and keep its co-batched neighbours bit-exact.
This module is the shared vocabulary: a structured exception hierarchy
(every engine-surfaced failure is a :class:`RequestError` subclass
carrying the offending ``rid``) and the closed set of per-request
terminal states recorded on ``Request.status``.

State machine (see docs/ARCHITECTURE.md, "Failure handling"):

    pending --admit--> live --ok--------------------> ok
       |                |---divergence--> quarantined --replay--> live
       |                |                     `--no checkpoint/2nd trip--> failed
       |                |---deadline------------------------------> timed_out
       |                `---corrupt restore blob------------------> failed
       |---deadline (queued / can't-meet estimate)--> timed_out / cancelled
       |---starved out (strict_tiers starve_ms)-----> timed_out
       `---watchdog (no progress) / max_iters-------> failed / cancelled

The engine NEVER raises one of these during :meth:`ServingEngine.run`:
they are attached to the failing request (``Request.error``) and the
request is moved to ``finished`` with a non-``"ok"`` status.  Raising is
reserved for caller bugs (e.g. submitting an out-of-vocab prompt).
"""
from __future__ import annotations

from typing import Optional

#: Closed set of terminal request states (``Request.status``).
#: ``ok``        — decoded to completion.
#: ``failed``    — quarantined by a fault (divergence after replay, blob
#:                 corruption, watchdog stall) — see ``Request.error``.
#: ``cancelled`` — never ran / cut short by policy (deadline-infeasible at
#:                 admission, ``run(max_iters=...)`` bail-out).
#: ``timed_out`` — the request's ``deadline_ms`` expired while queued or
#:                 in flight.
TERMINAL_STATES = ("ok", "failed", "cancelled", "timed_out")


class RequestError(Exception):
    """Base class for structured serving failures.

    ``rid`` names the offending request where one is known (blob
    corruption detected outside the engine carries ``rid=None``)."""

    def __init__(self, msg: str, *, rid: Optional[int] = None):
        self.rid = rid
        super().__init__(msg if rid is None else f"rid={rid}: {msg}")


class DeadlineExceeded(RequestError):
    """The request's ``deadline_ms`` budget is unmeetable or exhausted —
    rejected at admission (estimated latency exceeds the remaining
    budget) or cancelled in flight (queued / mid-prefill / mid-decode)."""


class DivergenceDetected(RequestError):
    """A decode burst or prefill chunk produced non-finite activations for
    this request's row (per-row on-device ``isfinite`` sentinel).  Raised
    terminally only after the one checkpoint-replay attempt also trips
    (or when no checkpoint exists to replay from)."""


class CacheCorruption(RequestError):
    """An offloaded cache blob failed validation on restore: key set
    differs from the slot template, per-key schema (shape/dtype) does not
    match, or a payload crc32 mismatches.  ``key`` names the first
    offending blob entry when the damage is key-local."""

    def __init__(self, msg: str, *, rid: Optional[int] = None,
                 key: Optional[str] = None):
        self.key = key
        super().__init__(msg if key is None else f"{msg} (key: {key})",
                         rid=rid)


class RecoveryFailed(RequestError):
    """A persisted request record could not be reconstructed at engine
    restart: the stored prompt fails its recorded crc32 (or the record is
    otherwise internally inconsistent), so neither restore-from-blob nor
    replay-from-prompt can produce the original stream.  Corrupt *blobs*
    never raise this — they degrade to replay-from-prompt; this is for
    records where even replay would decode a different request."""


class SlotStalled(RequestError):
    """The engine's no-progress watchdog tripped: N consecutive iterations
    decoded zero tokens and advanced no prefill chunk while work was
    queued — the stranded request is failed so the host loop can't hang
    forever behind it."""


class StarvationTimeout(RequestError):
    """A queued request waited past the scheduler's starvation bound
    (``starve_ms``) while outranked by higher-priority work.  Only the
    ``strict_tiers`` policy gives up this way — strict tiers can starve a
    low class indefinitely under sustained high-class load, and a
    structured failure (status ``timed_out``) beats rotting invisibly at
    the back of the queue.  ``weighted_fair`` honours the same bound by
    escalating (aging) instead of failing."""
