"""Step builders shared by dryrun / train / serve launchers.

Given (ModelConfig, WorkloadConfig, Mesh) a cell is built: the jittable
step function, ShapeDtypeStruct argument trees, and the in/out shardings
derived from the per-cell ShardingPlan.  Nothing here allocates device
memory — the dry-run lowers/compiles against specs only.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.config import ModelConfig, WorkloadConfig
from repro.core.workload import input_specs
from repro.distributed.sharding import ShardingPlan, plan_sharding, zero1_rules
from repro.models.lm import (init_lm_cache, lm_param_axes, model_param_defs,
                             init_lm_params)
from repro.models.params import tree_defs_map, is_def
from repro.serving.engine import make_decode_step, make_encode_step, \
    make_prefill_step
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclass
class Cell:
    cfg: ModelConfig
    wl: WorkloadConfig
    plan: ShardingPlan
    step: Callable
    args: Tuple[Any, ...]            # ShapeDtypeStruct trees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate: Tuple[int, ...] = ()     # donated arg indices (params/opt/cache)


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def param_sds(cfg: ModelConfig, dtype=None) -> Any:
    defs = model_param_defs(cfg)
    dt = jnp.dtype(dtype or cfg.param_dtype)
    return tree_defs_map(lambda d: jax.ShapeDtypeStruct(d.shape, dt), defs)


def param_shardings(cfg: ModelConfig, plan: ShardingPlan,
                    rules_plan: Optional[ShardingPlan] = None) -> Any:
    axes = lm_param_axes(cfg)
    sds = param_sds(cfg)
    rp = rules_plan or plan
    return jax.tree_util.tree_map(
        lambda ax, s: rp.named(ax, s.shape),
        axes, sds,
        is_leaf=lambda x: (isinstance(x, tuple)
                           and all(a is None or isinstance(a, str)
                                   for a in x)))


_CACHE_AXES = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", None),
    "v": ("layers", "batch", "kv_seq", "kv_heads", None),
    "conv": ("layers", "batch", None, "conv_dim"),
    "ssm": ("layers", "batch", "ssm_heads", None, None),
}


def cache_shardings(cache_sds, plan: ShardingPlan):
    def leaf_sharding(path, leaf):
        key = None
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str) and k in _CACHE_AXES:
                key = k
                break
        if key is None:
            return NamedSharding(plan.mesh, P())
        return plan.named(_CACHE_AXES[key], leaf.shape, activation=True)

    flat = jax.tree_util.tree_leaves_with_path(cache_sds)
    leaves = [leaf_sharding(p, l) for p, l in flat]
    treedef = jax.tree_util.tree_structure(cache_sds)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def input_shardings(cfg: ModelConfig, specs: Dict[str, Any],
                    plan: ShardingPlan) -> Dict[str, Any]:
    out = {}
    for name, sd in specs.items():
        if name == "features":
            out[name] = plan.named(("batch", "seq", None), sd.shape,
                                   activation=True)
        else:
            out[name] = plan.named(("batch", "seq"), sd.shape,
                                   activation=True)
    return out


def build_cell(cfg: ModelConfig, wl: WorkloadConfig, mesh, *,
               opt: Optional[OptConfig] = None,
               microbatches: int = 1,
               sequence_parallel: bool = False) -> Cell:
    if wl.kind != "train":
        microbatches = 1
    plan = plan_sharding(cfg, wl, mesh, microbatches=microbatches,
                         sequence_parallel=sequence_parallel)
    specs = input_specs(cfg, wl)
    in_sh_specs = input_shardings(cfg, specs, plan)

    if wl.kind == "train":
        opt = opt or OptConfig()
        psds = param_sds(cfg)                         # f32 master params
        osds = jax.eval_shape(functools.partial(init_opt_state, cfg=opt),
                              psds)
        psh = param_shardings(cfg, plan)
        zplan = zero1_rules(plan)
        osh = {"m": param_shardings(cfg, plan, zplan),
               "v": param_shardings(cfg, plan, zplan),
               "step": NamedSharding(mesh, P())}
        raw_step = make_train_step(cfg, opt, plan, microbatches=microbatches)

        def step(params, opt_state, batch):
            with plan.activations():
                return raw_step(params, opt_state, batch)

        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P())}
        return Cell(cfg, wl, plan, step,
                    args=(psds, osds, specs),
                    in_shardings=(psh, osh, in_sh_specs),
                    out_shardings=(psh, osh, metrics_sh),
                    donate=(0, 1))

    # inference cells: bf16 weights
    psds = param_sds(cfg, dtype=cfg.compute_dtype)
    psh = param_shardings(cfg, plan)
    logits_sh = NamedSharding(
        mesh, plan.spec(("batch", "seq", "vocab"), (1, 1, cfg.padded_vocab),
                        activation=True))

    if wl.kind == "prefill" and cfg.family in ("encoder", "audio"):
        raw = make_encode_step(cfg, plan)

        def step(params, inputs):
            with plan.activations():
                return raw(params, inputs)

        return Cell(cfg, wl, plan, step, args=(psds, specs),
                    in_shardings=(psh, in_sh_specs),
                    out_shardings=logits_sh)

    cache_fn = functools.partial(
        init_lm_cache, cfg, wl.global_batch, wl.seq_len,
        kv_repeat=plan.kv_repeat, shared_kv_repeat=plan.kv_repeat)
    csds = jax.eval_shape(cache_fn)
    csh = cache_shardings(csds, plan)

    if wl.kind == "prefill":
        raw = make_prefill_step(cfg, plan)

        def step(params, inputs, cache):
            with plan.activations():
                return raw(params, inputs, cache)

        return Cell(cfg, wl, plan, step, args=(psds, specs, csds),
                    in_shardings=(psh, in_sh_specs, csh),
                    out_shardings=(logits_sh, csh), donate=(2,))

    # decode: serve_step — one token against a seq_len cache
    raw = make_decode_step(cfg, plan)

    def step(params, token, cache):
        with plan.activations():
            return raw(params, token, cache)

    tok_sh = plan.named(("batch", None), specs["tokens"].shape,
                        activation=True)
    return Cell(cfg, wl, plan, step, args=(psds, specs["tokens"], csds),
                in_shardings=(psh, tok_sh, csh),
                out_shardings=(logits_sh, csh), donate=(2,))


def lower_cell(cell: Cell):
    with cell.plan.mesh:
        jitted = jax.jit(cell.step, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        return jitted.lower(*cell.args)
