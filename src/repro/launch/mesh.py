"""Production mesh definition.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.  Single pod = 16x16 (256 v5e chips); multi-pod
adds a leading "pod" axis (2 pods = 512 chips).  The "pod" axis carries
only data parallelism (and expert parallelism for MoE) — it maps onto DCN,
so nothing bandwidth-hungry (TP) is ever placed on it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # jax added sharding.AxisType (and the make_mesh axis_types kwarg) after
    # 0.4.x; older installs get the same Auto behavior by default
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
