import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves the distribution config is coherent without hardware: GSPMD
partitioning must succeed, the per-device memory analysis must fit, and
the compiled HLO feeds the roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell, subprocess each
  python -m repro.launch.dryrun --all --mesh multi

Outputs one JSON per cell under benchmarks/results/dryrun/.
"""
import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ASSIGNED                          # noqa: E402
from repro.core.config import SHAPES, TPU_V5E               # noqa: E402
from repro.core.hlo_analysis import analyze_hlo_text, xla_cost_dict  # noqa: E402
from repro.core.registry import get                         # noqa: E402
from repro.core.roofline import model_flops                 # noqa: E402
from repro.core.workload import applicable                  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_name  # noqa: E402
from repro.launch.steps import build_cell, lower_cell       # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

# Per-arch training-memory knobs (derived from the dry-run's own memory
# analysis: residual-stream scan carries ∝ L×B×S×D must fit alongside the
# optimizer).  MoE giants additionally keep Adam moments in bf16.
TRAIN_MICROBATCHES = {
    "qwen3-moe-235b-a22b": 16,
    "llama4-maverick-400b-a17b": 16,
    "glm4-9b": 8,
    "llama3-8b": 8,
    "llava-next-mistral-7b": 8,
    "mamba2-2.7b": 8,
    "zamba2-2.7b": 8,
}
BF16_OPT_STATE = {"qwen3-moe-235b-a22b", "llama4-maverick-400b-a17b"}


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             microbatches: int = 1, sequence_parallel: bool = False) -> dict:
    cfg = get(arch)
    wl = SHAPES[shape]
    ok, why = applicable(cfg, wl)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "applicable": ok, "skip_reason": why}
    if not ok:
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["mesh_shape"] = mesh_name(mesh)
    rec["chips"] = mesh.devices.size
    t0 = time.time()
    from repro.train.optimizer import OptConfig
    mb = TRAIN_MICROBATCHES.get(arch, microbatches)
    opt = OptConfig(state_dtype="bfloat16" if arch in BF16_OPT_STATE
                    else "float32")
    rec["train_knobs"] = {"microbatches": mb, "opt_state_dtype": opt.state_dtype,
                          "sequence_parallel": sequence_parallel}
    cell = build_cell(cfg, wl, mesh, opt=opt, microbatches=mb,
                      sequence_parallel=sequence_parallel)
    rec["plan"] = {"attn_mode": cell.plan.attn_mode,
                   "kv_repeat": cell.plan.kv_repeat,
                   "moe_groups": cell.plan.moe_groups,
                   "notes": list(cell.plan.notes)}
    lowered = lower_cell(cell)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gb": ma.argument_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "code_gb": getattr(ma, "generated_code_size_in_bytes", 0) / 1e9,
        "alias_gb": getattr(ma, "alias_size_in_bytes", 0) / 1e9,
        "hbm_gb": TPU_V5E.hbm_bytes / 1e9,
    }
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            - getattr(ma, "alias_size_in_bytes", 0) + ma.temp_size_in_bytes)
    rec["memory"]["live_gb"] = live / 1e9
    rec["memory"]["fits"] = bool(live <= TPU_V5E.hbm_bytes)

    xca = xla_cost_dict(compiled)
    rec["xla_cost"] = {"flops": xca.get("flops", 0.0),
                       "bytes": xca.get("bytes accessed", 0.0)}

    t0 = time.time()
    txt = compiled.as_text()
    import gzip
    with gzip.open(os.path.join(
            out_dir, f"{arch}__{shape}__{mesh_kind}.hlo.gz"), "wt") as f:
        f.write(txt)
    from repro.core.hlo_analysis import HloAnalyzer
    an = HloAnalyzer(txt)
    cost = an.summarize()
    fused = an.summarize_fused()
    rec["analyze_s"] = round(time.time() - t0, 2)
    rec["hlo"] = {
        "flops": cost.flops, "bytes": cost.bytes,
        "coll_bytes": cost.coll_bytes,
        "by_class": cost.by_class(),
        "by_scope": cost.by_scope(),
        "n_kernels": len(cost.kernels),
    }
    # the deployed-kernel (Pallas fused attn/ssd/conv/norm) memory model
    rec["hlo_fused"] = {
        "flops": fused.flops, "bytes": fused.bytes,
        "coll_bytes": fused.coll_bytes,
        "by_class": fused.by_class(),
    }
    rec["model_flops"] = model_flops(cfg, wl)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--timeout", type=int, default=1200)
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual stream (beyond-paper)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        archs = [args.arch] if args.arch else list(ASSIGNED)
        shapes = [args.shape] if args.shape else list(SHAPES)
        failures = []
        for arch in archs:
            for shape in shapes:
                for mk in meshes:
                    tag = f"{arch}__{shape}__{mk}"
                    path = os.path.join(args.out, tag + ".json")
                    if os.path.exists(path):
                        print(f"[skip existing] {tag}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mk,
                           "--out", args.out,
                           "--microbatches", str(args.microbatches)] \
                        + (["--sp"] if args.sp else [])
                    print(f"[run] {tag}", flush=True)
                    r = subprocess.run(cmd, timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append(tag)
        print("FAILURES:", failures if failures else "none")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    tag = f"{args.arch}__{args.shape}__{args.mesh}"
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                       microbatches=args.microbatches,
                       sequence_parallel=args.sp)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "applicable": True, "error": traceback.format_exc()}
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(rec["error"])
        sys.exit(1)
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if rec.get("applicable"):
        m = rec["memory"]
        print(f"[ok] {tag}: compile={rec['compile_s']}s "
              f"live={m['live_gb']:.2f}GB fits={m['fits']} "
              f"flops/dev={rec['hlo']['flops']:.3e} "
              f"coll={rec['hlo']['coll_bytes']:.3e}B")
    else:
        print(f"[n/a] {tag}: {rec['skip_reason']}")


if __name__ == "__main__":
    main()
