"""Serving launcher: slot-based continuous batching over a reduced model.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \\
      --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import reduced
from repro.core.registry import get, list_archs
from repro.models.lm import init_lm_params
from repro.serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    if cfg.family in ("encoder", "audio"):
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(2, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {tokens} tokens in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
