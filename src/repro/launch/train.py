"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \\
      --steps 100 --seq 512 --batch 8 --ckpt-dir /tmp/ckpt

On this CPU container it trains reduced configs end-to-end; on a real
cluster the same entry point is pointed at the production mesh (the
dry-run proves those configs compile)."""
from __future__ import annotations

import argparse

from repro.configs import reduced
from repro.core.registry import get, list_archs
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (default: reduced for CPU)")
    args = ap.parse_args()

    cfg = get(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    trainer = Trainer(
        cfg, OptConfig(lr=args.lr),
        TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10,
                      microbatches=args.microbatches),
        seq_len=args.seq, global_batch=args.batch)
    if trainer.maybe_restore():
        print(f"[restore] resumed at step {trainer.state.step}")
    state = trainer.run()
    print(f"done: {state.step} steps, final loss "
          f"{state.losses[-1]:.4f}, stragglers={state.straggler_steps}")


if __name__ == "__main__":
    main()
