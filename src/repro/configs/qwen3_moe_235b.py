"""qwen3-moe-235b-a22b — 128-expert top-8 MoE.
[hf:Qwen/Qwen3-30B-A3B; hf]  94L d_model=4096 64H (kv=4) expert d_ff=1536
vocab=151936, MoE every layer, qk-norm, head_dim=128."""
from repro.core.config import AttnConfig, ModelConfig, MoEConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    d_ff=1536,                      # per-expert ff
    vocab_size=151936,
    attn=AttnConfig(n_heads=64, n_kv_heads=4, head_dim=128,
                    rope_theta=1_000_000.0, qk_norm=True),
    moe=MoEConfig(n_experts=128, experts_per_token=8, d_ff_expert=1536,
                  capacity_factor=1.25),
    layer_pattern=("moe",),
), tags=("assigned", "moe"))
