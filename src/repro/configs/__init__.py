"""Assigned architecture pool + paper-model suite.

Importing this package registers every config with the model registry.
``--arch <id>`` anywhere in the launchers resolves through here.
"""
from __future__ import annotations

import dataclasses

from repro.core.config import AttnConfig, ModelConfig, MoEConfig, SSMConfig
from repro.core.registry import register

from repro.configs.zamba2_2p7b import CONFIG as zamba2_2p7b          # noqa: F401,E402
from repro.configs.hubert_xlarge import CONFIG as hubert_xlarge      # noqa: F401,E402
from repro.configs.qwen3_moe_235b import CONFIG as qwen3_moe_235b    # noqa: F401,E402
from repro.configs.llama4_maverick import CONFIG as llama4_maverick  # noqa: F401,E402
from repro.configs.glm4_9b import CONFIG as glm4_9b                  # noqa: F401,E402
from repro.configs.llama3_8b import CONFIG as llama3_8b              # noqa: F401,E402
from repro.configs.gemma3_1b import CONFIG as gemma3_1b              # noqa: F401,E402
from repro.configs.smollm_135m import CONFIG as smollm_135m          # noqa: F401,E402
from repro.configs.mamba2_2p7b import CONFIG as mamba2_2p7b          # noqa: F401,E402
from repro.configs.llava_next_mistral_7b import CONFIG as llava_next # noqa: F401,E402
from repro.configs import paper_models                               # noqa: F401,E402

ASSIGNED = (
    "zamba2-2.7b", "hubert-xlarge", "qwen3-moe-235b-a22b",
    "llama4-maverick-400b-a17b", "glm4-9b", "llama3-8b", "gemma3-1b",
    "smollm-135m", "mamba2-2.7b", "llava-next-mistral-7b",
)


def reduced(cfg: ModelConfig, *, d_model: int = 64, vocab: int = 256,
            n_units: int = 2) -> ModelConfig:
    """Shrink an arch to a CPU-smoke size, preserving family / layer pattern
    / head-grouping structure (same code paths, tiny shapes)."""
    unit = cfg.layer_pattern
    n_layers = len(unit) * n_units

    def shrink_attn(a):
        if a is None:
            return None
        kv = max(1, min(a.n_kv_heads, 2))
        heads = max(kv, min(a.n_heads, 4))
        heads = (heads // kv) * kv or kv
        return dataclasses.replace(
            a, n_heads=heads, n_kv_heads=kv, head_dim=d_model // 4,
            sliding_window=(8 if a.sliding_window else None),
            dense_cutoff=a.dense_cutoff)

    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, d_state=16, headdim=16, chunk=16)
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=8,
                                  experts_per_token=min(
                                      moe.experts_per_token, 2),
                                  d_ff_expert=d_model * 2)
    return dataclasses.replace(
        cfg, name=cfg.name + "-reduced", n_layers=n_layers, d_model=d_model,
        d_ff=d_model * 2 if cfg.d_ff else 0, vocab_size=vocab,
        attn=shrink_attn(cfg.attn), ssm=ssm, moe=moe,
        shared_attn=shrink_attn(cfg.shared_attn),
        shared_attn_d_ff=d_model * 2 if cfg.shared_attn_d_ff else 0,
        frontend_feature_dim=32 if cfg.frontend != "none" else 0,
        vocab_pad_multiple=16)
