"""llava-next-mistral-7b — Mistral-7B backbone + anyres vision tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  32L d_model=4096 32H
(kv=8) d_ff=14336 vocab=32000.  The vision tower is a stub: input_specs()
provides 576 precomputed 1024-d CLIP patch embeddings, projected and
prepended to the token stream (early fusion)."""
from repro.core.config import AttnConfig, ModelConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0),
    layer_pattern=("dense",),
    frontend="vision",
    frontend_feature_dim=1024,
), tags=("assigned", "vlm"))
