"""hubert-xlarge — encoder-only audio transformer backbone.
[arXiv:2106.07447; unverified]  48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (masked-prediction cluster targets).  The conv feature extractor
is a stub: input_specs() provides precomputed 512-d frame embeddings."""
from repro.core.config import AttnConfig, ModelConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=80, causal=False),
    layer_pattern=("encoder",),
    frontend="audio",
    frontend_feature_dim=512,
    act="gelu",
), tags=("assigned", "audio", "encoder"))
