"""zamba2-2.7b — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  The shared transformer block (one weight copy) is applied at
every 6th layer position, Zamba2-style."""
from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2, n_groups=1, chunk=128),
    layer_pattern=("mamba2",) * 5 + ("mamba2+shared",),
    shared_attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=80,
                           rope_theta=10_000.0),
    shared_attn_d_ff=10240,
    tie_embeddings=True,
), tags=("assigned", "hybrid"))
