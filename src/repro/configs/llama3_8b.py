"""llama3-8b — dense decoder, GQA, 128K vocab.
[arXiv:2407.21783; unverified]  32L d_model=4096 32H (kv=8) d_ff=14336
vocab=128256."""
from repro.core.config import AttnConfig, ModelConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                    rope_theta=500_000.0),
    layer_pattern=("dense",),
), tags=("assigned", "dense"))
