"""mamba2-2.7b — pure SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  64L d_model=2560 d_ff=0 vocab=50280,
ssm_state=128.  The paper's central subject; runs all four shapes
including long_500k."""
from repro.core.config import ModelConfig, SSMConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1, chunk=128),
    layer_pattern=("mamba2",),
    tie_embeddings=True,
), tags=("assigned", "ssm"))
