"""llama4-maverick-400b-a17b — interleaved MoE (every 2nd layer), top-1
routing with an always-on shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L d_model=5120 40H
(kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.

40 heads don't divide the 16-way model axis → the sharding plan falls back
to sequence-sharded attention and this config enables FSDP (params' d_model
dim over the data axis) so head-replicated attention weights stay cheap."""
from repro.core.config import AttnConfig, ModelConfig, MoEConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202048,
    attn=AttnConfig(n_heads=40, n_kv_heads=8, head_dim=128,
                    rope_theta=500_000.0),
    moe=MoEConfig(n_experts=128, experts_per_token=1, d_ff_expert=8192,
                  interleave_step=2, shared_expert=True,
                  capacity_factor=1.25),
    layer_pattern=("dense_moe", "moe"),
    fsdp=True,
), tags=("assigned", "moe"))
