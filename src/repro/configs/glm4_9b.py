"""glm4-9b — dense decoder, RoPE + GQA (kv=2).
[hf:THUDM/glm-4-9b; hf]  40L d_model=4096 32H (kv=2) d_ff=13696 vocab=151552."""
from repro.core.config import AttnConfig, ModelConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    d_ff=13696,
    vocab_size=151552,
    attn=AttnConfig(n_heads=32, n_kv_heads=2, head_dim=128,
                    rope_theta=10_000.0),
    layer_pattern=("dense",),
), tags=("assigned", "dense"))
