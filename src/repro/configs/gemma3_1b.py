"""gemma3-1b — 5:1 local:global attention, 256K vocab, tied embeddings.
[hf:google/gemma-3-1b-pt; unverified]  26L d_model=1152 4H (kv=1)
d_ff=6912 vocab=262144, sliding window 512, head_dim=256.

Sub-quadratic in 5/6 layers → long_500k RUNS for this arch (global-layer
KV at decode is sequence-sharded)."""
from repro.core.config import AttnConfig, ModelConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    d_ff=6912,
    vocab_size=262144,
    attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=256,
                    rope_theta=1_000_000.0, sliding_window=512),
    layer_pattern=("local", "local", "local", "local", "local", "dense"),
    tie_embeddings=True,
    act="gelu",
), tags=("assigned", "dense", "local-global"))
