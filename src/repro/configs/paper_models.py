"""The paper's own model suite (Table II) — used by the figure benchmarks.

Public configs; the Falcon-H1 parallel hybrid-head layout is approximated
with interleaved mamba2/attention layers (our block system is sequential;
noted in DESIGN.md §Arch-applicability).  Hymba (head-parallel hybrid) is
not reproduced for the same reason.
"""
from repro.core.config import AttnConfig, ModelConfig, SSMConfig
from repro.core.registry import register

QWEN25_05B = register(ModelConfig(
    name="qwen2.5-0.5b", family="dense", n_layers=24, d_model=896,
    d_ff=4864, vocab_size=151936,
    attn=AttnConfig(n_heads=14, n_kv_heads=2, head_dim=64,
                    rope_theta=1_000_000.0),
    layer_pattern=("dense",), tie_embeddings=True,
), tags=("paper", "dense"))

QWEN25_15B = register(ModelConfig(
    name="qwen2.5-1.5b", family="dense", n_layers=28, d_model=1536,
    d_ff=8960, vocab_size=151936,
    attn=AttnConfig(n_heads=12, n_kv_heads=2, head_dim=128,
                    rope_theta=1_000_000.0),
    layer_pattern=("dense",), tie_embeddings=True,
), tags=("paper", "dense"))

LLAMA32_1B = register(ModelConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048,
    d_ff=8192, vocab_size=128256,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=64,
                    rope_theta=500_000.0),
    layer_pattern=("dense",), tie_embeddings=True,
), tags=("paper", "dense"))

PHI3_MINI = register(ModelConfig(
    name="phi-3-mini", family="dense", n_layers=32, d_model=3072,
    d_ff=8192, vocab_size=32064,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=96),
    layer_pattern=("dense",),
), tags=("paper", "dense"))

MAMBA1_130M = register(ModelConfig(
    name="mamba-130m", family="ssm", n_layers=24, d_model=768, d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=16, variant="mamba1", expand=2, conv_kernel=4),
    layer_pattern=("mamba1",), tie_embeddings=True,
), tags=("paper", "ssm", "mamba1"))

MAMBA2_130M = register(ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768, d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1, chunk=128),
    layer_pattern=("mamba2",), tie_embeddings=True,
), tags=("paper", "ssm"))

MAMBA2_780M = register(ModelConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536, d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1, chunk=128),
    layer_pattern=("mamba2",), tie_embeddings=True,
), tags=("paper", "ssm"))

# Falcon-H1-0.5B: parallel hybrid heads (attention + Mamba-2 side by side
# in every layer — the real Falcon-H1 topology via the hybrid_par block).
FALCON_H1_05B = register(ModelConfig(
    name="falcon-h1-0.5b", family="hybrid", n_layers=18, d_model=1024,
    d_ff=4096, vocab_size=32784,
    attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=128),
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1, chunk=128),
    layer_pattern=("hybrid_par",), tie_embeddings=True,
), tags=("paper", "hybrid"))

# Hymba-1.5B proxy: also a parallel hybrid-head design (attention + SSM
# heads in the same layer).
HYMBA_15B = register(ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=24, d_model=1536,
    d_ff=5504, vocab_size=32001,
    attn=AttnConfig(n_heads=12, n_kv_heads=2, head_dim=128),
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, n_groups=1, chunk=128),
    layer_pattern=("hybrid_par",), tie_embeddings=True,
), tags=("paper", "hybrid"))

# Zamba2-1.2B (Fig. 8a): mamba2 backbone + shared attention, no GQA.
ZAMBA2_12B = register(ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    d_ff=8192, vocab_size=32000,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2, n_groups=1, chunk=128),
    layer_pattern=("mamba2", "mamba2+shared"),
    # the shared block operates on concat(x, embed) in Zamba2 → 128-d heads
    shared_attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=128),
    shared_attn_d_ff=8192, tie_embeddings=True,
), tags=("paper", "hybrid"))
