"""smollm-135m — llama-architecture small model.
[hf:HuggingFaceTB/SmolLM-135M; hf]  30L d_model=576 9H (kv=3) d_ff=1536
vocab=49152.  9 heads don't divide the model axis → sequence-sharded
attention; the model axis still shards ff and vocab."""
from repro.core.config import AttnConfig, ModelConfig
from repro.core.registry import register

CONFIG = register(ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    d_ff=1536,
    vocab_size=49152,
    attn=AttnConfig(n_heads=9, n_kv_heads=3, head_dim=64,
                    rope_theta=10_000.0),
    layer_pattern=("dense",),
    tie_embeddings=True,
), tags=("assigned", "dense"))
