from repro.kernels.conv1d.ops import causal_conv1d  # noqa: F401
