from repro.kernels.conv1d.ops import causal_conv1d, conv1d_decode_step  # noqa: F401
