"""Pallas TPU kernel for causal depthwise conv1d (streaming, halo carried
in VMEM scratch across sequential sequence blocks)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params


def _conv_kernel(x_ref, w_ref, b_ref, init_ref, y_ref, carry, *,
                 k: int, bs: int, silu: bool):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _():
        carry[...] = init_ref[0].astype(jnp.float32)

    xb = x_ref[0].astype(jnp.float32)                  # [bs, bc]
    full = jnp.concatenate([carry[...], xb], axis=0)   # [bs+k-1, bc]
    w = w_ref[...].astype(jnp.float32)                 # [bc, k]
    y = jnp.zeros_like(xb)
    for i in range(k):
        y = y + full[i:i + bs, :] * w[:, i][None, :]
    y = y + b_ref[...].astype(jnp.float32).reshape(1, -1)
    if silu:
        y = y * jax.nn.sigmoid(y)
    y_ref[0] = y.astype(y_ref.dtype)
    carry[...] = full[bs:, :]


def causal_conv1d_pallas(x, w, b, *, initial_state: Optional[jax.Array] = None,
                         activation: str = "silu", block_seq: int = 512,
                         block_ch: int = 256, interpret: bool = False
                         ) -> Tuple[jax.Array, jax.Array]:
    bsz, s, c = x.shape
    k = w.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((bsz, k - 1, c), x.dtype)
    bs = min(block_seq, s)
    bc = min(block_ch, c)
    assert s % bs == 0 and c % bc == 0, (s, bs, c, bc)
    grid = (bsz, c // bc, s // bs)

    kern = functools.partial(_conv_kernel, k=k, bs=bs,
                             silu=(activation == "silu"))
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bc), lambda bi, ci, si: (bi, si, ci)),
            pl.BlockSpec((bc, k), lambda bi, ci, si: (ci, 0)),
            pl.BlockSpec((bc,), lambda bi, ci, si: (ci,)),
            pl.BlockSpec((1, k - 1, bc), lambda bi, ci, si: (bi, 0, ci)),
        ],
        out_specs=pl.BlockSpec((1, bs, bc), lambda bi, ci, si: (bi, si, ci)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, c), x.dtype),
        scratch_shapes=[pltpu.VMEM((k - 1, bc), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, b, initial_state)
    xp = jnp.concatenate([initial_state.astype(x.dtype), x], axis=1)
    new_state = xp[:, s:, :]
    return y, new_state.astype(x.dtype)
