"""Backend-dispatching entry points for causal depthwise conv1d."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import dispatch
from repro.kernels.conv1d import ref as _ref


def causal_conv1d(x, w, b, *, initial_state: Optional[jax.Array] = None,
                  activation: str = "silu") -> Tuple[jax.Array, jax.Array]:
    backend = dispatch.get_backend()
    with jax.named_scope("conv1d"):
        if backend == "ref":
            return _ref.causal_conv1d_ref(x, w, b, initial_state, activation)
        from repro.kernels.conv1d.kernel import causal_conv1d_pallas
        return causal_conv1d_pallas(x, w, b, initial_state=initial_state,
                                    activation=activation,
                                    interpret=(backend == "interpret"))

# The per-token conv decode step lives in kernels.decode_fused, fused with
# the SSM state update (no standalone entry point anymore).
