"""Pure-jnp oracle for the causal depthwise conv1d (Mamba's second custom op)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def causal_conv1d_ref(x: jax.Array, w: jax.Array, b: jax.Array,
                      initial_state: Optional[jax.Array] = None,
                      activation: str = "silu") -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, C]; w: [C, K]; b: [C].  Returns (y [B,S,C], state [B,K-1,C]).

    state carries the last K-1 inputs for streaming decode.
    """
    bsz, s, c = x.shape
    k = w.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((bsz, k - 1, c), x.dtype)
    xp = jnp.concatenate([initial_state.astype(x.dtype), x], axis=1)
    # depthwise conv as a sum of K shifted scalings (K is tiny, typically 4)
    y = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(k):
        y = y + xp[:, i:i + s, :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    if activation == "silu":
        y = jax.nn.silu(y)
    new_state = xp[:, s:, :] if k > 1 else jnp.zeros((bsz, 0, c), x.dtype)
    return y.astype(x.dtype), new_state.astype(x.dtype)


def conv1d_decode_ref(state: jax.Array, x_t: jax.Array, w: jax.Array,
                      b: jax.Array, activation: str = "silu"
                      ) -> Tuple[jax.Array, jax.Array]:
    """state: [B, K-1, C]; x_t: [B, C]. Returns (y_t [B,C], new_state)."""
    k = w.shape[-1]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # [B, K, C]
    y = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "silu":
        y = jax.nn.silu(y)
    return y.astype(x_t.dtype), window[:, 1:, :]
