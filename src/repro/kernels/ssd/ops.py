"""Jit'd public entry points for the SSD operator (backend-dispatching)."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.ssd import ref as _ref


def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
                initial_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    backend = dispatch.get_backend()
    with jax.named_scope("ssd_core"):
        if backend == "ref":
            return _ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk,
                                        initial_state=initial_state)
        from repro.kernels.ssd.kernel import ssd_pallas
        return ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                          initial_state=initial_state,
                          interpret=(backend == "interpret"))


def ssd_chunked_raw(x, dt_raw, dt_bias, A_log, Bm, Cm, D, *,
                    chunk: int = 128,
                    initial_state: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Fused-ingest variant: raw dt + A_log preprocessing inside the kernel
    scope (matches the CUDA kernel's fusion boundary)."""
    with jax.named_scope("ssd_core"):
        dt, A = _ref.preprocess_dt_A(dt_raw, dt_bias, A_log)
    return ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk,
                       initial_state=initial_state)


# The per-token SSD decode step lives in kernels.decode_fused, fused with
# the conv shift step (no standalone entry point anymore).
