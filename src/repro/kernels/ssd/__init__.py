from repro.kernels.ssd.ops import ssd_chunked, ssd_decode_step  # noqa: F401
