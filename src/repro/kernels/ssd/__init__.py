from repro.kernels.ssd.ops import ssd_chunked  # noqa: F401
