"""Pure-jnp oracle for the Mamba-2 SSD (state-space dual) operator.

Shapes (following the Mamba-2 paper):
  x  : [B, S, H, P]   per-head inputs (P = headdim)
  dt : [B, S, H]      post-softplus step sizes
  A  : [H]            negative per-head decay rates
  Bm : [B, S, G, N]   input projections (G groups, N = d_state)
  Cm : [B, S, G, N]   output projections
  D  : [H]            skip connection
Returns y : [B, S, H, P] and final state [B, H, P, N].

Two implementations:
  * ``ssd_sequential`` — O(S) token-by-token recurrence (slow, ground truth).
  * ``ssd_chunked_ref`` — the chunked dual form (matmul-heavy, what the
    Pallas kernel implements): intra-chunk attention-like term + inter-chunk
    state recurrence.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _expand_groups(t: jax.Array, n_heads: int) -> jax.Array:
    """[B, S, G, N] -> [B, S, H, N] by repeating each group."""
    g = t.shape[2]
    return jnp.repeat(t, n_heads // g, axis=2)


def ssd_sequential(x, dt, A, Bm, Cm, D,
                   initial_state: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    Bh = _expand_groups(Bm, h).astype(jnp.float32)
    Ch = _expand_groups(Cm, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(hstate, inp):
        xt, dtt, bt, ct = inp           # [b,h,p], [b,h], [b,h,n], [b,h,n]
        da = jnp.exp(dtt * Af)          # [b,h]
        upd = (dtt[..., None] * bt)[:, :, None, :] * xt[..., None]
        hstate = hstate * da[..., None, None] + upd
        yt = jnp.einsum("bhpn,bhn->bhp", hstate, ct)
        return hstate, yt

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3) + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hT


def _segsum(a: jax.Array) -> jax.Array:
    """log-space segment sums: out[..., i, j] = sum_{j<k<=i} a[..., k], -inf j>i."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]   # sum (j, i]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def preprocess_dt_A(dt_raw, dt_bias, A_log):
    """The fused kernels ingest RAW dt and A_log (like the CUDA
    `mamba_split_conv1d_scan_combined`): softplus + sign happen in-register,
    never round-tripping [B,S,H] through HBM."""
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + dt_bias.astype(jnp.float32))
    A = -jnp.exp(A_log.astype(jnp.float32))
    return dt, A


def ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk: int = 128,
                    initial_state: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD (matmul dual form), numerically matching ssd_sequential."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, f"seq {s} must be a multiple of chunk {chunk}"
    nc, q = s // chunk, chunk
    Bh = _expand_groups(Bm, h).astype(jnp.float32).reshape(b, nc, q, h, n)
    Ch = _expand_groups(Cm, h).astype(jnp.float32).reshape(b, nc, q, h, n)
    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Af = A.astype(jnp.float32)

    da = dtf * Af[None, None, None, :]           # [b,nc,q,h] log decay steps
    da_t = da.transpose(0, 1, 3, 2)              # [b,nc,h,q]
    cum = jnp.cumsum(da_t, axis=-1)              # inclusive cumsum
    # intra-chunk: Y_diag[i] = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
    L = jnp.exp(_segsum(da_t))                   # [b,nc,h,q,q]
    cb = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)
    dtx = dtf[..., None] * xf                    # [b,nc,q,h,p]
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp", cb, L, dtx)

    # per-chunk final states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # [b,nc,h,q]
    states = jnp.einsum("bchq,bcqhn,bcqhp->bchpn",
                        decay_to_end, Bh, dtx)

    # inter-chunk recurrence over chunk summaries
    chunk_decay = jnp.exp(cum[..., -1])          # [b,nc,h]
    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def carry_fn(hprev, inp):
        st, dec = inp                            # [b,h,p,n], [b,h]
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev                       # emit state *entering* chunk

    (hT, h_in) = jax.lax.scan(
        carry_fn, h0, (states.transpose(1, 0, 2, 3, 4),
                       chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)         # [b,nc,h,p,n]

    # off-diagonal: Y_off[i] = C_i . h_in * exp(cum_i)
    decay_from_start = jnp.exp(cum)              # [b,nc,h,q]
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", Ch, h_in, decay_from_start)

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), hT


def ssd_decode_ref(state, x_t, dt_t, A, B_t, C_t, D
                   ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. state: [B,H,P,N]; x_t: [B,H,P]; dt_t: [B,H];
    B_t/C_t: [B,G,N]."""
    h = x_t.shape[1]
    Bh = jnp.repeat(B_t, h // B_t.shape[1], axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C_t, h // C_t.shape[1], axis=1).astype(jnp.float32)
    xf, dtf = x_t.astype(jnp.float32), dt_t.astype(jnp.float32)
    da = jnp.exp(dtf * A.astype(jnp.float32))
    upd = (dtf[..., None] * Bh)[:, :, None, :] * xf[..., None]
    new_state = state * da[..., None, None] + upd
    y = (jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
         + xf * D.astype(jnp.float32)[None, :, None])
    return y.astype(x_t.dtype), new_state
