"""Pallas TPU kernel for the chunked Mamba-2 SSD scan.

TPU adaptation of the GPU `mamba_split_conv1d_scan_combined` insight
("minimize HBM I/O"): one pass over the sequence, chunk working set held in
VMEM, intra-chunk math expressed as dense matmuls on the MXU
(C·Bᵀ ⊙ decay) · (Δ⊙X), and the inter-chunk recurrence carried across
sequential grid steps in a VMEM scratch accumulator.

Grid: (B, H, S/chunk) — the chunk dimension is innermost and iterated
sequentially by the TPU, so the [P, N] state scratch is a legal carry.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, init_ref,
                y_ref, final_ref, state, *, nc: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        state[...] = init_ref[0, 0].astype(jnp.float32)

    xb = x_ref[0, :, 0, :].astype(jnp.float32)        # [Q, P]
    dtb = dt_ref[0, :, :].astype(jnp.float32)         # [Q, 1]
    a = a_ref[0, 0].astype(jnp.float32)               # scalar
    bb = b_ref[0, :, 0, :].astype(jnp.float32)        # [Q, N]
    cb = c_ref[0, :, 0, :].astype(jnp.float32)        # [Q, N]
    dskip = d_ref[0, 0].astype(jnp.float32)

    da = dtb * a                                      # [Q, 1] log-decay steps
    cum = jnp.cumsum(da, axis=0)                      # [Q, 1]
    # intra-chunk: (C Bᵀ ⊙ L) (Δ ⊙ X)
    seg = cum - cum.reshape(1, chunk)                 # [Q, Q] cum_i - cum_j
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(qi >= kj, jnp.exp(seg), 0.0)     # [Q, Q]
    scores = jax.lax.dot(cb, bb.T,
                         preferred_element_type=jnp.float32) * lmat
    dtx = dtb * xb                                    # [Q, P]
    y = jax.lax.dot(scores, dtx, preferred_element_type=jnp.float32)
    # inter-chunk: C · state_in, decayed from chunk start
    y = y + jnp.exp(cum) * jax.lax.dot(cb, state[...].T,
                                       preferred_element_type=jnp.float32)
    y = y + dskip * xb
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update: state_out = state_in * e^{cum_last} + (Δ X ⊙ d2e)ᵀ B
    last = cum[chunk - 1]                             # [1]
    d2e = jnp.exp(last.reshape(1, 1) - cum)           # [Q, 1]
    state[...] = (state[...] * jnp.exp(last)[0]
                  + jax.lax.dot((dtx * d2e).T, bb,
                                preferred_element_type=jnp.float32))

    @pl.when(ci == nc - 1)
    def _():
        final_ref[0, 0] = state[...]


def ssd_pallas(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
               initial_state: Optional[jax.Array] = None,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    a2 = A.reshape(h, 1)
    d2 = D.reshape(h, 1)
    dt3 = dt.reshape(b, s, h)

    kern = functools.partial(_ssd_kernel, nc=nc, chunk=chunk)
    grid = (b, h, nc)
    heads_per_group = h // g
    y, final = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // heads_per_group, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // heads_per_group, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt3, a2, Bm, Cm, d2, initial_state)
    return y, final
