"""Oracle for the decode-attention kernel (single query vs long KV cache)."""
from repro.kernels.flash.ref import decode_attention_ref  # noqa: F401
