from repro.kernels.attn_decode.ops import decode_attention  # noqa: F401
