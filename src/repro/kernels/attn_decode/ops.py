"""Backend-dispatching entry point for decode attention.

Every backend (including "ref") routes through here, so the model layer has
a single decode-attention call site; the ref backend lowers to the dense
masked oracle, the others to the split-K flash-decode Pallas kernel.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.attn_decode import ref as _ref


def decode_attention(q, k, v, *, valid_len,
                     block_s: int = 1024,
                     split_k: Optional[int] = None) -> jax.Array:
    """q: [B, H, d]; k, v: [B, KVH, S, d]; valid_len: scalar or [B].

    ``split_k`` (None = auto, overridable via ``REPRO_DECODE_SPLIT_K``)
    selects how many parallel partial-softmax segments the Pallas kernel
    uses over the KV axis; results are identical for every value."""
    backend = dispatch.get_backend()
    with jax.named_scope("attn_core"):
        if backend == "ref":
            return _ref.decode_attention_ref(q, k, v, valid_len=valid_len)
        if split_k is None:
            split_k = dispatch.decode_split_k()
        from repro.kernels.attn_decode.kernel import decode_attention_pallas
        return decode_attention_pallas(q, k, v, valid_len=valid_len,
                                       block_s=block_s, split_k=split_k,
                                       interpret=(backend == "interpret"))
