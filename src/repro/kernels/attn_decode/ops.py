"""Backend-dispatching entry point for decode attention."""
from __future__ import annotations

import jax

from repro.kernels import dispatch
from repro.kernels.attn_decode import ref as _ref


def decode_attention(q, k, v, *, valid_len) -> jax.Array:
    backend = dispatch.get_backend()
    with jax.named_scope("attn_core"):
        if backend == "ref":
            return _ref.decode_attention_ref(q, k, v, valid_len=valid_len)
        from repro.kernels.attn_decode.kernel import decode_attention_pallas
        return decode_attention_pallas(q, k, v, valid_len=valid_len,
                                       interpret=(backend == "interpret"))
