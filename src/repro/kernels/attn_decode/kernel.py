"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Memory-bound by design (the paper's decode-phase bottleneck): each KV block
is streamed HBM->VMEM exactly once; the GQA query group [G, d] stays
resident; (m, l, acc) carried in VMEM scratch over the sequential KV-block
grid dimension.

Two long-KV provisions:

* **Per-row early-exit past ``valid_len``**: a KV block whose start lies at
  or beyond the row's live prefix is predicated off with ``pl.when`` — no
  MXU work and no VMEM traffic is issued for the dead tail, so a row at
  pos 1K inside a 64K cache reads ~1K rows, not 64K.
* **Split-K partial-softmax reduction**: the KV axis is divided into
  ``split_k`` independent segments that run under a *parallel* grid
  dimension, each emitting unnormalised partials ``(acc, m, l)``; a cheap
  jnp epilogue merges them with the standard online-softmax combine.  For
  long KV this turns one serial O(S) walk into ``split_k`` concurrent
  O(S/split_k) walks (flash-decoding), which is what keeps a single query
  token from under-utilising the chip at the paper's 57K+ contexts.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, om_ref, ol_ref,
                   m_s, l_s, acc_s, *, bs: int, ns: int, scale: float):
    """Grid (B, KVH, split_k, ns): the last dim walks this split's KV blocks
    sequentially; splits/batch/heads are parallel.  Emits this split's
    unnormalised partials; the wrapper merges across splits."""
    sp = pl.program_id(2)
    si = pl.program_id(3)

    @pl.when(si == 0)
    def _():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    valid = len_ref[pl.program_id(0)]
    # early-exit: this block starts at or past the row's live prefix
    run = (sp * ns + si) * bs < valid

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, d]
        k = k_ref[0, 0, 0].astype(jnp.float32)         # [bs, d]
        v = v_ref[0, 0, 0].astype(jnp.float32)         # [bs, d]
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = ((sp * ns + si) * bs
                + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc_s[...] = (acc_s[...] * corr
                      + jax.lax.dot(p.astype(v.dtype), v,
                                    preferred_element_type=jnp.float32))

    @pl.when(si == ns - 1)
    def _():
        o_ref[0, 0, 0] = acc_s[...]
        om_ref[0, 0, 0] = m_s[...]
        ol_ref[0, 0, 0] = l_s[...]


def decode_attention_pallas(q, k, v, *, valid_len, block_s: int = 1024,
                            split_k: Optional[int] = None,
                            interpret: bool = False) -> jax.Array:
    """q: [B, H, d]; k, v: [B, KVH, S, d]; valid_len: scalar or [B].

    ``split_k`` (None = auto) partitions the KV axis into that many
    parallel partial-softmax segments; outputs are identical for every
    value (the combine is the exact online-softmax merge)."""
    b, h, d = q.shape
    kvh, s = k.shape[1], k.shape[2]
    g = h // kvh
    bs = min(block_s, s)
    nb = -(-s // bs)
    if split_k is None:
        # one extra segment per 4 KV blocks, capped: short caches stay
        # serial (no combine overhead), long caches fan out
        split_k = max(1, min(8, nb // 4))
    split_k = min(split_k, nb)
    ns = -(-nb // split_k)                       # blocks per split
    pad = split_k * ns * bs - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    qg = q.reshape(b, kvh, g, d)
    kern = functools.partial(_decode_kernel, bs=bs, ns=ns,
                             scale=1.0 / math.sqrt(d))
    acc, m, l = pl.pallas_call(
        kern,
        grid=(b, kvh, split_k, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, sp, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1, bs, d),
                         lambda bi, hi, sp, si: (bi, hi, sp, si, 0)),
            pl.BlockSpec((1, 1, 1, bs, d),
                         lambda bi, hi, sp, si: (bi, hi, sp, si, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, d),
                         lambda bi, hi, sp, si: (bi, hi, sp, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, 1),
                         lambda bi, hi, sp, si: (bi, hi, sp, 0, 0)),
            pl.BlockSpec((1, 1, 1, g, 1),
                         lambda bi, hi, sp, si: (bi, hi, sp, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kvh, split_k, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, split_k, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, kvh, split_k, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(vl, qg, k.reshape(b, kvh, split_k, ns * bs, d),
      v.reshape(b, kvh, split_k, ns * bs, d))
    # exact online-softmax merge of the split partials (empty splits carry
    # m = NEG_INF, l = 0 and vanish; NEG_INF is finite, so no inf - inf)
    m_all = jnp.max(m, axis=2, keepdims=True)              # [B,KVH,1,G,1]
    alpha = jnp.exp(m - m_all)
    l_all = jnp.sum(l * alpha, axis=2)                     # [B,KVH,G,1]
    out = jnp.sum(acc * alpha, axis=2) / jnp.maximum(l_all, 1e-37)
    return out.astype(q.dtype).reshape(b, h, d)
