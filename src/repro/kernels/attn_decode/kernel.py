"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Memory-bound by design (the paper's decode-phase bottleneck): each KV block
is streamed HBM->VMEM exactly once; the GQA query group [G, d] stays
resident; (m, l, acc) carried in VMEM scratch over sequential KV blocks.
The valid-length mask supports partially-filled caches.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                   bs: int, ns: int, scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    valid = len_ref[pl.program_id(0)]
    run = si * bs < valid

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bs, d]
        v = v_ref[0, 0].astype(jnp.float32)            # [bs, d]
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = si * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc_s[...] = (acc_s[...] * corr
                      + jax.lax.dot(p.astype(v.dtype), v,
                                    preferred_element_type=jnp.float32))

    @pl.when(si == ns - 1)
    def _():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-37)
                       ).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, *, valid_len, block_s: int = 1024,
                            interpret: bool = False) -> jax.Array:
    """q: [B, H, d]; k, v: [B, KVH, S, d]; valid_len: scalar or [B]."""
    b, h, d = q.shape
    kvh, s = k.shape[1], k.shape[2]
    g = h // kvh
    bs = min(block_s, s)
    pad = (-s) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ns = k.shape[2] // bs
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    qg = q.reshape(b, kvh, g, d)
    kern = functools.partial(_decode_kernel, bs=bs, ns=ns,
                             scale=1.0 / math.sqrt(d))
    out = pl.pallas_call(
        kern,
        grid=(b, kvh, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda bi, hi, si: (bi, hi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(vl, qg, k, v)
    return out.reshape(b, h, d)
