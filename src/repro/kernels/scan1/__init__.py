from repro.kernels.scan1.ops import selective_scan_op  # noqa: F401
