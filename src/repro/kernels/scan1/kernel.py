"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation of the GPU kernel's "state stays in SRAM" insight: the
[block_ch, N] state lives in VMEM scratch across sequential sequence-block
grid steps; within a block the recurrence is unrolled (VPU element-wise) —
d_state is small (16) so each step is a [bc, N] fma + a tiny contraction.

Grid: (B, C/block_ch, S/block_seq), sequence innermost (sequential).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, init_ref,
                 y_ref, final_ref, h_s, *, bs: int, ns: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _():
        h_s[...] = init_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                 # [bc, N]
    xb = x_ref[0].astype(jnp.float32)                  # [bs, bc]
    dtb = dt_ref[0].astype(jnp.float32)                # [bs, bc]
    bb = b_ref[0].astype(jnp.float32)                  # [bs, N]
    cb = c_ref[0].astype(jnp.float32)                  # [bs, N]
    dsk = d_ref[...].astype(jnp.float32)               # [bc, 1]

    h = h_s[...]
    ys = []
    for t in range(bs):                                # unrolled recurrence
        da = jnp.exp(dtb[t][:, None] * a)              # [bc, N]
        h = h * da + (dtb[t] * xb[t])[:, None] * bb[t][None, :]
        ys.append(jnp.sum(h * cb[t][None, :], axis=1)) # [bc]
    h_s[...] = h
    y = jnp.stack(ys, axis=0) + xb * dsk.T             # [bs, bc]
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(si == ns - 1)
    def _():
        final_ref[0] = h

def selective_scan_pallas(x, dt, A, Bm, Cm, D, *,
                          initial_state: Optional[jax.Array] = None,
                          block_seq: int = 16, block_ch: int = 256,
                          interpret: bool = False
                          ) -> Tuple[jax.Array, jax.Array]:
    b, s, c = x.shape
    n = A.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((b, c, n), jnp.float32)
    bs = min(block_seq, s)
    bc = min(block_ch, c)
    assert s % bs == 0 and c % bc == 0, (s, bs, c, bc)
    grid = (b, c // bc, s // bs)
    d2 = D.reshape(c, 1)
    kern = functools.partial(_scan_kernel, bs=bs, ns=s // bs)
    y, final = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bc), lambda bi, ci, si: (bi, si, ci)),
            pl.BlockSpec((1, bs, bc), lambda bi, ci, si: (bi, si, ci)),
            pl.BlockSpec((bc, n), lambda bi, ci, si: (ci, 0)),
            pl.BlockSpec((1, bs, n), lambda bi, ci, si: (bi, si, 0)),
            pl.BlockSpec((1, bs, n), lambda bi, ci, si: (bi, si, 0)),
            pl.BlockSpec((bc, 1), lambda bi, ci, si: (ci, 0)),
            pl.BlockSpec((1, bc, n), lambda bi, ci, si: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bc), lambda bi, ci, si: (bi, si, ci)),
            pl.BlockSpec((1, bc, n), lambda bi, ci, si: (bi, ci, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, c), x.dtype),
            jax.ShapeDtypeStruct((b, c, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bc, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, d2, initial_state)
    return y, final
