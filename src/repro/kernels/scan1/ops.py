"""Backend-dispatching entry for the Mamba-1 selective scan."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import dispatch
from repro.kernels.scan1 import ref as _ref


def selective_scan_op(x, dt, A, Bm, Cm, D, *,
                      initial_state: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    backend = dispatch.get_backend()
    with jax.named_scope("ssm_core"):
        if backend == "ref":
            return _ref.selective_scan_ref(x, dt, A, Bm, Cm, D,
                                           initial_state=initial_state)
        from repro.kernels.scan1.kernel import selective_scan_pallas
        return selective_scan_pallas(x, dt, A, Bm, Cm, D,
                                     initial_state=initial_state,
                                     interpret=(backend == "interpret"))
