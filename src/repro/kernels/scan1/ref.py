"""Oracle for the Mamba-1 selective scan (S6) kernel.

Shapes: x, dt: [B, S, C] (C = d_inner, dt post-softplus); A: [C, N];
Bm, Cm: [B, S, N]; D: [C]; h: [B, C, N].
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, A, Bm, Cm, D,
                       initial_state: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    b, s, c = x.shape
    n = A.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    h0 = (jnp.zeros((b, c, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # [b,c],[b,c],[b,n],[b,n]
        da = jnp.exp(dtt[..., None] * Af[None])   # [b,c,n]
        h = h * da + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, ct)
        return h, y

    hT, ys = jax.lax.scan(step, h0, (xf.transpose(1, 0, 2),
                                     dtf.transpose(1, 0, 2),
                                     Bf.transpose(1, 0, 2),
                                     Cf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + xf * D.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), hT
