"""Pallas TPU flash-attention (prefill) kernel.

Online-softmax over KV blocks with (m, l, acc) carried in VMEM scratch
across the sequential innermost grid dimension.  Causal and sliding-window
masks are evaluated per block; fully-masked blocks are skipped with
``pl.when`` (predicated off on TPU — no MXU work issued).

Live-prefix contract (chunked prefill + KV bucketing): the grid's batch
dimension makes the causal block-skip *per row* — row b's chunk at offset
``q_offset[b]`` skips every KV block past ``q_offset[b] + bq - 1``, so a
short-prefix row in a mixed-length group never reads the long row's KV
blocks, and rows read at most their own live prefix even before the
serving layer slices the cache to the bucket.  The bucket (static ``Skv``)
then bounds what is *resident*, the skip bounds what is *touched*.

Ring-buffer contract (chunked prefill over rolling sliding-window caches):
with the static ``ring_len`` set, the first ``ring_len`` KV slots are a
ring with modulus ``window`` and per-row write cursor ``kv_wrap[b]``
(a second SMEM scalar riding next to ``q_offset``); the remaining slots
are the in-flight chunk at absolute positions ``kv_wrap[b] + (j -
ring_len)``.  The kernel recovers each slot's absolute position with the
modular formula and masks causally against it — the ring is unrolled
in-mask, never materialized as a rolled copy.  Block-skip: chunk-tail
coverage keeps the causal skip on its absolute positions; ring coverage
runs unless it lies entirely past an unwrapped cursor (slot order is not
position order, so no other ring skip is sound).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(qoff_ref, kvwrap_ref, q_ref, k_ref, v_ref, o_ref,
                  m_s, l_s, acc_s, *,
                  bq: int, bk: int, nk: int, causal: bool,
                  window: Optional[int], scale: float, kv_len: int,
                  ring_len: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # per-row query offset (chunked prefill); zeros for plain prefill
    q_start = qi * bq + qoff_ref[0]
    k_start = ki * bk
    # block-level skip: k block entirely in the future (causal) or entirely
    # out of the attention window
    run = True
    if ring_len is None:
        if causal:
            run = k_start <= q_start + bq - 1
        if window is not None:
            run = jnp.logical_and(run, (q_start - (k_start + bk - 1)) < window)
    else:
        # ring slots run only if any was ever written: slot order !=
        # position order, but an unwrapped ring (wrap < window) has
        # written exactly slots [0, wrap), so ring coverage fully past
        # the cursor is dead.  Chunk-tail coverage keeps the causal skip
        # on its absolute positions.  A block may span both regions —
        # either live half forces it to run.
        wrap = kvwrap_ref[0]
        ring_live = jnp.logical_and(
            k_start < ring_len,
            jnp.logical_or(wrap >= window, k_start < wrap))
        tail_first = wrap + jnp.maximum(k_start - ring_len, 0)
        tail_live = jnp.logical_and(k_start + bk > ring_len,
                                    tail_first <= q_start + bq - 1)
        run = jnp.logical_or(ring_live, tail_live)

    @pl.when(run)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        jidx = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if ring_len is None:
            kpos = jidx
            mask = jidx < kv_len
        else:
            wrap = kvwrap_ref[0]
            ring_pos = wrap - 1 - jnp.mod(wrap - 1 - jidx, window)
            tail_pos = wrap + (jidx - ring_len)
            kpos = jnp.where(jidx < ring_len, ring_pos, tail_pos)
            # kpos < 0 marks never-written ring slots
            mask = (jidx < kv_len) & (kpos >= 0)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]                              # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_s[...] = m_new
        acc_s[...] = (acc_s[...] * corr
                      + jax.lax.dot(p.astype(v.dtype), v,
                                    preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-37)
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           q_offset=None,
                           kv_wrap=None, ring_len: Optional[int] = None,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, d]; k, v: [B, KVH, Skv, d] -> [B, H, Sq, d].

    ``q_offset`` (None, scalar, or [B] int32) shifts the causal/window mask
    per batch row: query i of row b sits at absolute position
    ``q_offset[b] + i`` (chunked prefill against a KV cache that already
    holds earlier chunks).  The offsets ride in SMEM; the block-skip
    predicate folds them in, so fully-masked KV blocks are still skipped.

    ``kv_wrap`` ([B] int32 write cursors) + static ``ring_len`` switch the
    first ``ring_len`` KV slots into a ring buffer with modulus ``window``
    (see module docstring) — the layout used when a chunk prefills against
    a rolling sliding-window cache."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    if ring_len is not None:
        assert causal and window is not None and kv_wrap is not None, \
            "ring KV layout requires causal attention, a window and kv_wrap"
    if q_offset is None:
        q_offset = 0
    qoff = jnp.broadcast_to(jnp.atleast_1d(
        jnp.asarray(q_offset, jnp.int32)), (b,))
    kwrap = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(
        0 if kv_wrap is None else kv_wrap, jnp.int32)), (b,))
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    kv_len = skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // bq
    nk = k.shape[2] // bk
    gsz = h // kvh
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window,
        scale=1.0 / math.sqrt(d), kv_len=kv_len, ring_len=ring_len)
    out = pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, qi, ki: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda bi, hi, qi, ki: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // gsz, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // gsz, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qoff, kwrap, q, k, v)
    return out[:, :, :sq] if pad_q else out
