"""Pure-jnp oracle for flash attention: exact masked softmax attention.

Layout: q [B, H, Sq, d]; k, v [B, KVH, Skv, d] (GQA: H % KVH == 0).

Ring-buffer layout (chunked prefill over rolling sliding-window caches):
when ``kv_wrap`` is given, the first ``ring_len`` KV slots are a ring
buffer with modulus ``window`` whose per-row write cursor is ``kv_wrap``
(slot j holds the most recent token with absolute position % window == j
written before the chunk), and the remaining slots are the in-flight
chunk at absolute positions ``kv_wrap + (j - ring_len)``.  The masks are
evaluated against those absolute positions, so the ring is "unrolled"
without ever materializing a rolled copy of the cache.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def ring_kv_positions(kv_wrap: jax.Array, window: int, ring_len: int,
                      skv: int) -> jax.Array:
    """Absolute key positions [B, Skv] of a ring+chunk KV layout.

    ``kv_wrap`` ([B] int32): per-row write cursor (tokens written so far).
    Slots ``j < ring_len`` are ring slots: the newest token with
    ``pos % window == j`` strictly before the cursor (negative = never
    written — callers must mask those out).  Slots ``j >= ring_len`` are
    the current chunk: absolute position ``kv_wrap + (j - ring_len)``.
    """
    j = jnp.arange(skv, dtype=jnp.int32)[None, :]
    w = jnp.asarray(kv_wrap, jnp.int32)[:, None]
    ring = w - 1 - jnp.mod(w - 1 - j, window)
    tail = w + (j - ring_len)
    return jnp.where(j < ring_len, ring, tail)


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  q_offset=0,
                  kv_wrap: Optional[jax.Array] = None,
                  ring_len: Optional[int] = None) -> jax.Array:
    """``q_offset``: scalar or [B] per-row query-position offset (chunked
    prefill: query i of row b sits at absolute position q_offset[b] + i).

    ``kv_wrap``/``ring_len`` enable the ring-buffer KV layout (see module
    docstring); they require ``causal`` and a ``window``."""
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    skv = k.shape[2]
    qg = q.reshape(b, kvh, h // kvh, sq, d)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    off = jnp.atleast_1d(jnp.asarray(q_offset))
    qpos = jnp.arange(sq)[None, :] + off[:, None]              # [Bb, Sq]
    if kv_wrap is not None:
        assert causal and window is not None and ring_len is not None, \
            "ring KV layout requires causal attention and a window"
        kpos = ring_kv_positions(kv_wrap, window, ring_len, skv)[:, None, :]
        mask = kpos >= 0                                       # never-written
    else:
        kpos = jnp.arange(skv)[None, None, :]
        mask = jnp.ones((off.shape[0], sq, skv), bool)
    if causal:
        mask = mask & (qpos[:, :, None] >= kpos)
    if window is not None:
        mask = mask & ((qpos[:, :, None] - kpos) < window)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def decode_attention_ref(q, k, v, *, valid_len) -> jax.Array:
    """q: [B, H, d]; k,v: [B, KVH, S, d]; valid_len: scalar or [B]."""
    b, h, d = q.shape
    kvh = k.shape[1]
    qg = q.reshape(b, kvh, h // kvh, d)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kpos = jnp.arange(k.shape[2])
    vl = jnp.asarray(valid_len)
    vl = vl[:, None, None, None] if vl.ndim == 1 else vl
    s = jnp.where(kpos[None, None, None, :] < vl, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
