"""Pure-jnp oracle for flash attention: exact masked softmax attention.

Layout: q [B, H, Sq, d]; k, v [B, KVH, Skv, d] (GQA: H % KVH == 0).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  q_offset=0) -> jax.Array:
    """``q_offset``: scalar or [B] per-row query-position offset (chunked
    prefill: query i of row b sits at absolute position q_offset[b] + i)."""
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    qg = q.reshape(b, kvh, h // kvh, sq, d)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    off = jnp.atleast_1d(jnp.asarray(q_offset))
    qpos = jnp.arange(sq)[None, :] + off[:, None]              # [Bb, Sq]
    kpos = jnp.arange(k.shape[2])[None, None, :]
    mask = jnp.ones((off.shape[0], sq, k.shape[2]), bool)
    if causal:
        mask &= qpos[:, :, None] >= kpos
    if window is not None:
        mask &= (qpos[:, :, None] - kpos) < window
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def decode_attention_ref(q, k, v, *, valid_len) -> jax.Array:
    """q: [B, H, d]; k,v: [B, KVH, S, d]; valid_len: scalar or [B]."""
    b, h, d = q.shape
    kvh = k.shape[1]
    qg = q.reshape(b, kvh, h // kvh, d)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kpos = jnp.arange(k.shape[2])
    vl = jnp.asarray(valid_len)
    vl = vl[:, None, None, None] if vl.ndim == 1 else vl
    s = jnp.where(kpos[None, None, None, :] < vl, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
