"""Backend-dispatching entry point for (prefill) attention."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.flash import ref as _ref


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    q_offset=None) -> jax.Array:
    """``q_offset`` (None, scalar, or [B] int32): per-row query-position
    offset for chunked prefill against an already-filled KV prefix.

    Callers bound ``Skv`` to the live prefix via KV bucketing
    (``repro.serving.bucketing``); inside the kernel the per-row causal
    block-skip early-exits past each row's ``q_offset + Sq``."""
    backend = dispatch.get_backend()
    with jax.named_scope("attn_core"):
        if backend == "ref":
            return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                      q_offset=0 if q_offset is None
                                      else q_offset)
        from repro.kernels.flash.kernel import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset,
                                      interpret=(backend == "interpret"))
