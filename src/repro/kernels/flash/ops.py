"""Backend-dispatching entry point for (prefill) attention."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.flash import ref as _ref


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    q_offset=None,
                    kv_wrap=None,
                    ring_len: Optional[int] = None) -> jax.Array:
    """``q_offset`` (None, scalar, or [B] int32): per-row query-position
    offset for chunked prefill against an already-filled KV prefix.

    ``kv_wrap`` ([B] int32) + static ``ring_len``: ring-buffer KV layout
    for chunked prefill over rolling sliding-window caches — the first
    ``ring_len`` KV slots are a ring with modulus ``window`` and per-row
    write cursor ``kv_wrap``, the rest are the in-flight chunk (see
    ``repro.kernels.flash.ref.ring_kv_positions``).

    Callers bound ``Skv`` to the live prefix via KV bucketing
    (``repro.serving.bucketing``); inside the kernel the per-row causal
    block-skip early-exits past each row's ``q_offset + Sq``."""
    backend = dispatch.get_backend()
    with jax.named_scope("attn_core"):
        if backend == "ref":
            return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                      q_offset=0 if q_offset is None
                                      else q_offset,
                                      kv_wrap=kv_wrap, ring_len=ring_len)
        from repro.kernels.flash.kernel import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset,
                                      kv_wrap=kv_wrap, ring_len=ring_len,
                                      interpret=(backend == "interpret"))
