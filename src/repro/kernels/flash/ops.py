"""Backend-dispatching entry point for (prefill) attention."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.flash import ref as _ref


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None) -> jax.Array:
    backend = dispatch.get_backend()
    with jax.named_scope("attn_core"):
        if backend == "ref":
            return _ref.attention_ref(q, k, v, causal=causal, window=window)
        from repro.kernels.flash.kernel import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=(backend == "interpret"))
