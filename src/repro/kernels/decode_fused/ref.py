"""Pure-jnp oracle for the fused decode-step operators.

These reproduce — op for op, cast for cast — the composition the Mamba
blocks previously inlined (conv1d shift step -> projections -> state
update), so routing the decode path through this module is bitwise
identical on the "ref" backend.  The Pallas kernels in ``kernel.py`` fuse
the same sequence into one VMEM-resident pass per batch row.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.conv1d.ref import conv1d_decode_ref
from repro.kernels.ssd.ref import ssd_decode_ref


def mamba2_decode_fused_ref(conv_state, ssm_state, xbc_t, conv_w, conv_b,
                            dt_raw, dt_bias, A_log, D, *, n_groups: int,
                            d_state: int, headdim: int
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """conv_state: [B,K-1,C]; ssm_state: [B,H,P,N]; xbc_t: [B,C] (pre-conv
    packed x|B|C); dt_raw: [B,H].  Returns (y [B,H,P], conv_state',
    ssm_state' [B,H,P,N] f32)."""
    xbc, new_conv = conv1d_decode_ref(conv_state, xbc_t, conv_w, conv_b)
    gn = n_groups * d_state
    di = xbc.shape[-1] - 2 * gn
    b = xbc.shape[0]
    xs = xbc[..., :di]
    bm = xbc[..., di:di + gn].reshape(b, n_groups, d_state)
    cm = xbc[..., di + gn:].reshape(b, n_groups, d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + dt_bias.astype(jnp.float32))
    A = -jnp.exp(A_log.astype(jnp.float32))
    y, new_ssm = ssd_decode_ref(ssm_state.astype(jnp.float32),
                                xs.reshape(b, di // headdim, headdim),
                                dt, A, bm, cm, D)
    return y, new_conv, new_ssm


def mamba1_decode_fused_ref(conv_state, ssm_state, xi_t, conv_w, conv_b,
                            x_proj, dt_proj, dt_bias, A_log, D, *,
                            d_state: int, dt_rank: int
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """conv_state: [B,K-1,di]; ssm_state: [B,di,N]; xi_t: [B,di] (pre-conv).
    Returns (y [B,di] f32, conv_state', ssm_state' [B,di,N] f32)."""
    xi, new_conv = conv1d_decode_ref(conv_state, xi_t, conv_w, conv_b)
    dt_ = xi.dtype
    proj = xi @ x_proj.astype(dt_)
    dt_low = proj[..., :dt_rank]
    bm = proj[..., dt_rank:dt_rank + d_state]
    cm = proj[..., dt_rank + d_state:]
    dt = jax.nn.softplus((dt_low @ dt_proj.astype(dt_)).astype(jnp.float32)
                         + dt_bias.astype(jnp.float32))
    A = -jnp.exp(A_log.astype(jnp.float32))
    h = ssm_state.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A[None])
    dBx = (dt * xi.astype(jnp.float32))[..., None] \
        * bm.astype(jnp.float32)[:, None, :]
    h = h * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, cm.astype(jnp.float32))
    y = y + xi.astype(jnp.float32) * D.astype(jnp.float32)
    return y, new_conv, h
