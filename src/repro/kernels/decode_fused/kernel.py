"""Pallas TPU kernels for the fused decode step (conv shift + SSM update).

The decode hot loop is memory-bound: per token each Mamba layer must read
and rewrite its conv window and recurrent state.  Run eagerly, that is
four HBM round-trips (conv read/write, state read/write) plus the
intermediate dA/dBx tensors.  These kernels follow the paper's
"minimize HBM I/O, keep state resident" discipline: one grid step per
batch row pulls the row's working set into VMEM once, performs

  conv window shift -> silu -> (projections) -> softplus(dt)
  h' = h * exp(dt*A) + dt * B * x      y = C . h' + D * x

in-register, and writes back only the new window, new state, and y.

Grid: (B,) — rows are independent; everything per-row fits VMEM
comfortably (largest real shape: [H, P, N] f32 state, a few MB).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu  # noqa: F401  (memory spaces)

from repro.kernels.dispatch import tpu_compiler_params


def _conv_step(conv_ref, x_ref, w_ref, b_ref):
    """Shared conv shift step: returns (activated [1, C] f32, window [K, C])."""
    window = jnp.concatenate([conv_ref[0].astype(jnp.float32),
                              x_ref[...].astype(jnp.float32)], axis=0)
    w = w_ref[...].astype(jnp.float32)                 # [C, K]
    y = jnp.sum(window * w.T, axis=0, keepdims=True)   # [1, C]
    y = y + b_ref[...].astype(jnp.float32).reshape(1, -1)
    y = y * jax.nn.sigmoid(y)                          # silu
    return y, window


def _m2_kernel(conv_ref, x_ref, w_ref, b_ref, dt_ref, dtb_ref, al_ref, d_ref,
               ssm_ref, y_ref, nconv_ref, nssm_ref, *,
               di: int, g: int, n: int, h: int, p: int):
    xbc, window = _conv_step(conv_ref, x_ref, w_ref, b_ref)
    # match the ref's dtype round-trip at the conv boundary
    xbc = xbc.astype(x_ref.dtype).astype(jnp.float32)
    xs = xbc[0, :di].reshape(h, p)
    bm = xbc[0, di:di + g * n].reshape(g, n)
    cm = xbc[0, di + g * n:].reshape(g, n)
    dt = jax.nn.softplus(dt_ref[...].astype(jnp.float32)
                         + dtb_ref[...].astype(jnp.float32).reshape(1, -1))
    a = -jnp.exp(al_ref[...].astype(jnp.float32)).reshape(1, -1)  # [1, H]
    da = jnp.exp(dt * a)                               # [1, H]
    bh = jnp.repeat(bm, h // g, axis=0)                # [H, N]
    ch = jnp.repeat(cm, h // g, axis=0)
    upd = (dt.T * bh)[:, None, :] * xs[:, :, None]     # [H, P, N]
    hnew = ssm_ref[0] * da.T[:, :, None] + upd
    y = jnp.sum(hnew * ch[:, None, :], axis=-1)        # [H, P]
    y = y + xs * d_ref[...].astype(jnp.float32).reshape(-1, 1)
    y_ref[0] = y.astype(y_ref.dtype)
    nssm_ref[0] = hnew
    nconv_ref[0] = window[1:].astype(nconv_ref.dtype)


def mamba2_decode_fused_pallas(conv_state, ssm_state, xbc_t, conv_w, conv_b,
                               dt_raw, dt_bias, A_log, D, *, n_groups: int,
                               d_state: int, headdim: int,
                               interpret: bool = False
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, km1, c = conv_state.shape
    k = km1 + 1
    g, n, p = n_groups, d_state, headdim
    di = c - 2 * g * n
    h = di // p
    kern = functools.partial(_m2_kernel, di=di, g=g, n=n, h=h, p=p)
    y, nconv, nssm = pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, k - 1, c), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, c), lambda bi: (bi, 0)),
            pl.BlockSpec((c, k), lambda bi: (0, 0)),
            pl.BlockSpec((c,), lambda bi: (0,)),
            pl.BlockSpec((1, h), lambda bi: (bi, 0)),
            pl.BlockSpec((h,), lambda bi: (0,)),
            pl.BlockSpec((h,), lambda bi: (0,)),
            pl.BlockSpec((h,), lambda bi: (0,)),
            pl.BlockSpec((1, h, p, n), lambda bi: (bi, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, p), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, k - 1, c), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda bi: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, p), xbc_t.dtype),
            jax.ShapeDtypeStruct((b, k - 1, c),
                                 jnp.result_type(conv_state.dtype,
                                                 xbc_t.dtype)),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(conv_state, xbc_t, conv_w, conv_b, dt_raw, dt_bias, A_log, D, ssm_state)
    return y, nconv, nssm


def _m1_kernel(conv_ref, x_ref, w_ref, b_ref, xp_ref, dtp_ref, dtb_ref,
               al_ref, d_ref, ssm_ref, y_ref, nconv_ref, nssm_ref, *,
               di: int, n: int, dtr: int):
    xi, window = _conv_step(conv_ref, x_ref, w_ref, b_ref)
    xi = xi.astype(x_ref.dtype).astype(jnp.float32)    # [1, di]
    proj = jax.lax.dot(xi, xp_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)  # [1, dtr+2N]
    # the ref emits the projections in the input dtype — round to match
    proj = proj.astype(x_ref.dtype).astype(jnp.float32)
    dt_low = proj[:, :dtr]
    bm = proj[:, dtr:dtr + n]                          # [1, N]
    cm = proj[:, dtr + n:]                             # [1, N]
    dt_in = jax.lax.dot(dt_low, dtp_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    dt_in = dt_in.astype(x_ref.dtype).astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_in + dtb_ref[...].astype(jnp.float32).reshape(1, -1))  # [1, di]
    a = -jnp.exp(al_ref[...].astype(jnp.float32))      # [di, N]
    dA = jnp.exp(dt.T * a)                             # [di, N]
    dBx = (dt * xi).T * bm                             # [di, N]
    hnew = ssm_ref[0] * dA + dBx
    y = jnp.sum(hnew * cm, axis=-1, keepdims=True).T   # [1, di]
    y = y + xi * d_ref[...].astype(jnp.float32).reshape(1, -1)
    y_ref[...] = y
    nssm_ref[0] = hnew
    nconv_ref[0] = window[1:].astype(nconv_ref.dtype)


def mamba1_decode_fused_pallas(conv_state, ssm_state, xi_t, conv_w, conv_b,
                               x_proj, dt_proj, dt_bias, A_log, D, *,
                               d_state: int, dt_rank: int,
                               interpret: bool = False
                               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, km1, di = conv_state.shape
    k = km1 + 1
    n, dtr = d_state, dt_rank
    f = dtr + 2 * n
    kern = functools.partial(_m1_kernel, di=di, n=n, dtr=dtr)
    y, nconv, nssm = pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, k - 1, di), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, di), lambda bi: (bi, 0)),
            pl.BlockSpec((di, k), lambda bi: (0, 0)),
            pl.BlockSpec((di,), lambda bi: (0,)),
            pl.BlockSpec((di, f), lambda bi: (0, 0)),
            pl.BlockSpec((dtr, di), lambda bi: (0, 0)),
            pl.BlockSpec((di,), lambda bi: (0,)),
            pl.BlockSpec((di, n), lambda bi: (0, 0)),
            pl.BlockSpec((di,), lambda bi: (0,)),
            pl.BlockSpec((1, di, n), lambda bi: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, di), lambda bi: (bi, 0)),
            pl.BlockSpec((1, k - 1, di), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, di, n), lambda bi: (bi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, di), jnp.float32),
            jax.ShapeDtypeStruct((b, k - 1, di),
                                 jnp.result_type(conv_state.dtype,
                                                 xi_t.dtype)),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(conv_state, xi_t, conv_w, conv_b, x_proj, dt_proj, dt_bias, A_log, D,
      ssm_state)
    return y, nconv, nssm
