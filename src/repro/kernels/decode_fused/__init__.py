from repro.kernels.decode_fused.ops import (  # noqa: F401
    mamba1_decode_fused, mamba2_decode_fused,
)
