"""Backend-dispatching entry points for the fused decode step.

One call = one Mamba layer's whole per-token recurrence: conv1d shift step,
(for mamba1) the dt/B/C projections, softplus, and the state update
``h' = h*exp(dt*A) + dt*B*x`` with readout ``y = C.h' + D*x``.  The "ref"
backend is bitwise identical to the previously-inlined composition; the
Pallas backend fuses it into one VMEM-resident kernel per batch row
(interpret=True on CPU via ``REPRO_KERNEL_BACKEND=interpret``).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels import dispatch
from repro.kernels.decode_fused import ref as _ref


def mamba2_decode_fused(conv_state, ssm_state, xbc_t, conv_w, conv_b,
                        dt_raw, dt_bias, A_log, D, *, n_groups: int,
                        d_state: int, headdim: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Mamba-2 (SSD) decode step. Returns (y [B,H,P], conv', ssm')."""
    backend = dispatch.get_backend()
    with jax.named_scope("decode_fused"):
        if backend == "ref":
            return _ref.mamba2_decode_fused_ref(
                conv_state, ssm_state, xbc_t, conv_w, conv_b, dt_raw,
                dt_bias, A_log, D, n_groups=n_groups, d_state=d_state,
                headdim=headdim)
        from repro.kernels.decode_fused.kernel import \
            mamba2_decode_fused_pallas
        return mamba2_decode_fused_pallas(
            conv_state, ssm_state, xbc_t, conv_w, conv_b, dt_raw, dt_bias,
            A_log, D, n_groups=n_groups, d_state=d_state, headdim=headdim,
            interpret=(backend == "interpret"))


def mamba1_decode_fused(conv_state, ssm_state, xi_t, conv_w, conv_b,
                        x_proj, dt_proj, dt_bias, A_log, D, *,
                        d_state: int, dt_rank: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Mamba-1 (S6) decode step. Returns (y [B,di] f32, conv', ssm')."""
    backend = dispatch.get_backend()
    with jax.named_scope("decode_fused"):
        if backend == "ref":
            return _ref.mamba1_decode_fused_ref(
                conv_state, ssm_state, xi_t, conv_w, conv_b, x_proj,
                dt_proj, dt_bias, A_log, D, d_state=d_state,
                dt_rank=dt_rank)
        from repro.kernels.decode_fused.kernel import \
            mamba1_decode_fused_pallas
        return mamba1_decode_fused_pallas(
            conv_state, ssm_state, xi_t, conv_w, conv_b, x_proj, dt_proj,
            dt_bias, A_log, D, d_state=d_state, dt_rank=dt_rank,
            interpret=(backend == "interpret"))
