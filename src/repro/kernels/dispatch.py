"""Kernel backend selection.

Backends:
  * "ref"       — pure-jnp oracle (used for the CPU multi-pod dry-run; GSPMD
                  partitions it; named_scope tags keep the operator taxonomy).
  * "pallas"    — Pallas TPU kernels (Mosaic). The deployment path on TPU.
  * "interpret" — Pallas kernels executed with interpret=True (CPU validation).

Default: "ref" on CPU, "pallas" on TPU.  Override with set_backend(), the
``use_backend`` context manager, or the REPRO_KERNEL_BACKEND environment
variable (read once per call site: ``REPRO_KERNEL_BACKEND=interpret pytest``
runs the whole suite through the Pallas interpreter).
"""
from __future__ import annotations

import os
import threading

import jax

_LOCAL = threading.local()


def tpu_compiler_params(**kwargs):
    """jax renamed pltpu.TPUCompilerParams -> CompilerParams across versions;
    build whichever this install provides."""
    import jax.experimental.pallas.tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def decode_split_k():
    """Split-K override for the flash-decode kernel: ``REPRO_DECODE_SPLIT_K``
    pins the number of parallel partial-softmax KV segments; unset or any
    value < 1 (e.g. 0) lets the kernel pick from the KV length."""
    env = os.environ.get("REPRO_DECODE_SPLIT_K")
    if not env:
        return None
    val = int(env)
    return val if val >= 1 else None


def _env_flag(name: str, default: bool = True) -> bool:
    env = os.environ.get(name)
    if env is None or env.strip() == "":
        return default
    return env.strip().lower() not in ("0", "false", "no", "off")


def prefill_kv_buckets() -> bool:
    """``REPRO_PREFILL_KV_BUCKETS`` (default on): KV bucketing of chunked
    prefill.  Off = every chunk attends the full-extent cache — a debug
    escape hatch for bucket-related miscompares (outputs are bit-identical
    either way; only FLOPs/IO and compile counts change)."""
    return _env_flag("REPRO_PREFILL_KV_BUCKETS")


def ring_buckets() -> bool:
    """``REPRO_RING_BUCKETS`` (default on): allow bucket-slicing rolling
    (ring-buffer) KV caches while their live prefix hasn't wrapped.  Off =
    ring caches always span the full window inside bucketed programs (the
    append-only leaves still slice) — safe either way, useful to isolate
    ring-slice interactions."""
    return _env_flag("REPRO_RING_BUCKETS")


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    try:
        plat = jax.devices()[0].platform
    except Exception:  # pragma: no cover
        plat = "cpu"
    return "pallas" if plat == "tpu" else "ref"


def get_backend() -> str:
    return getattr(_LOCAL, "backend", None) or default_backend()


def set_backend(name: str) -> None:
    assert name in ("ref", "pallas", "interpret"), name
    _LOCAL.backend = name


class use_backend:
    def __init__(self, name: str):
        self.name = name

    def __enter__(self):
        self.prev = getattr(_LOCAL, "backend", None)
        set_backend(self.name)

    def __exit__(self, *exc):
        _LOCAL.backend = self.prev
