"""Logical-axis sharding rules and the per-(arch × workload × mesh) plan.

Everything in the model code is written with *logical* axis names
("batch", "seq", "heads", "ff", "experts", ...).  A :class:`ShardingPlan`
maps logical axes to mesh axes for one (ModelConfig, WorkloadConfig, Mesh)
cell, deciding between head-sharded and sequence-sharded attention, the
expert-parallel layout, KV-head replication, and ZeRO-1 optimizer sharding.

GSPMD keeps global semantics: the model code never changes, only the rules.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import ModelConfig, WorkloadConfig

MeshAxes = Union[None, str, Tuple[str, ...]]

_CTX = threading.local()


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint from logical axes, if a plan is active."""
    plan: Optional[ShardingPlan] = getattr(_CTX, "plan", None)
    if plan is None:
        return x
    spec = plan.spec(tuple(axes), x.shape, activation=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(plan.mesh, spec))


class _Activation:
    def __init__(self, plan):
        self.plan = plan

    def __enter__(self):
        self.prev = getattr(_CTX, "plan", None)
        _CTX.plan = self.plan
        return self.plan

    def __exit__(self, *exc):
        _CTX.plan = self.prev


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


@dataclass
class ShardingPlan:
    mesh: Mesh
    param_rules: Dict[str, MeshAxes]
    act_rules: Dict[str, MeshAxes]
    kv_repeat: int = 1
    moe_groups: int = 1
    attn_mode: str = "head"         # "head" | "seq"
    notes: Tuple[str, ...] = ()

    # ---- spec construction -------------------------------------------------
    def spec(self, axes: Sequence[Optional[str]], shape: Sequence[int],
             activation: bool = False) -> P:
        rules = self.act_rules if activation else self.param_rules
        used: set = set()
        parts = []
        for dim, name in zip(shape, axes):
            mapped = rules.get(name) if name is not None else None
            if mapped is None:
                parts.append(None)
                continue
            maxes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            maxes = tuple(a for a in maxes if a not in used)
            size = _axis_size(self.mesh, maxes)
            if size <= 1 or dim % size != 0:
                # try a prefix of the axes that divides
                while maxes and (dim % _axis_size(self.mesh, maxes) != 0):
                    maxes = maxes[:-1]
                if not maxes:
                    parts.append(None)
                    continue
            used.update(maxes)
            parts.append(maxes[0] if len(maxes) == 1 else maxes)
        return P(*parts)

    def named(self, axes: Sequence[Optional[str]], shape: Sequence[int],
              activation: bool = False) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape, activation))

    def params_sharding(self, axes_tree, shapes_tree):
        return jax.tree_util.tree_map(
            lambda ax, sh: self.named(ax, sh),
            axes_tree, shapes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))

    def activations(self) -> _Activation:
        return _Activation(self)

    @property
    def data_size(self) -> int:
        return _axis_size(self.mesh, self.act_rules.get("batch"))

    @property
    def model_size(self) -> int:
        return _axis_size(self.mesh, self.param_rules.get("ff"))


def _batch_axes(mesh: Mesh, global_batch: int) -> MeshAxes:
    """Pick the largest prefix of (pod, data) that divides the batch."""
    cand = [a for a in ("pod", "data") if a in mesh.shape]
    while cand and global_batch % _axis_size(mesh, tuple(cand)) != 0:
        cand.pop()
    return tuple(cand) if cand else None


def plan_sharding(cfg: ModelConfig, wl: WorkloadConfig, mesh: Mesh,
                  microbatches: int = 1,
                  sequence_parallel: bool = False) -> ShardingPlan:
    model = "model" if "model" in mesh.shape else None
    model_size = mesh.shape.get("model", 1)
    notes = []

    # ---- attention mode ----------------------------------------------------
    kv_repeat, attn_mode = 1, "head"
    if cfg.attn is not None and model is not None:
        H, KV = cfg.attn.n_heads, cfg.attn.n_kv_heads
        if H % model_size == 0 and model_size % KV == 0:
            kv_repeat = model_size // KV
            attn_mode = "head"
        elif H % model_size == 0 and KV % model_size == 0:
            kv_repeat, attn_mode = 1, "head"
        else:
            attn_mode = "seq"
            notes.append(f"heads ({H}/{KV}) not divisible by model={model_size}: "
                         "sequence-sharded attention")

    batch = _batch_axes(mesh, wl.global_batch)
    if batch is None:
        notes.append(f"global_batch={wl.global_batch} < data-parallel size: "
                     "batch replicated (long-context single-stream cell)")

    # sequence sharding: in seq attention mode (or batch-replicated decode),
    # put seq / kv_seq on the model axis (context parallelism).
    seq_axes: MeshAxes = None
    kv_seq_axes: MeshAxes = None
    if attn_mode == "seq":
        seq_axes = model
        kv_seq_axes = model
    # KV caches store exact (unreplicated) kv heads; when those can't shard
    # over the model axis, shard the cache's sequence dim instead.
    if (cfg.attn is not None and model is not None
            and cfg.attn.n_kv_heads % model_size != 0):
        kv_seq_axes = model
    if batch is None and cfg.attn is not None:
        # single-stream decode: shard the KV cache over data too
        if attn_mode == "seq":
            kv_seq_axes = ("data", "model") if "data" in mesh.shape else model

    heads_axes = model if attn_mode == "head" else None

    fsdp_axes: MeshAxes = None
    if getattr(cfg, "fsdp", False) and "data" in mesh.shape:
        fsdp_axes = "data"
        notes.append("FSDP: params' d_model dim sharded over data (ZeRO-3)")

    param_rules: Dict[str, MeshAxes] = {
        "embed": fsdp_axes,
        "layers": None,
        "heads": heads_axes,
        "kv_heads": heads_axes,
        "ff": model,
        "vocab": model,
        "ssm_heads": model,
        "conv_dim": model,
        "ssm_groups": None,
        "dstate": None,
        "experts": ("pod", "data") if "pod" in mesh.shape else "data",
        "expert_ff": model,
        "dt_rank": None,
    }
    act_rules: Dict[str, MeshAxes] = {
        "batch": batch,
        "seq": seq_axes,
        "kv_seq": kv_seq_axes,
        "heads": heads_axes,
        "kv_heads": heads_axes,
        "embed": None,
        "ff": model,
        "vocab": model,
        "ssm_heads": model,
        "conv_dim": model,
        # dispatch/combine (token-major) shard experts on the model axis;
        # ex_in/ex_out (expert-major) shard experts on the data axis — the
        # reshard between them is the EP all-to-all.  Capacity rows TP-shard.
        "experts": model,
        "experts_ep": ("pod", "data") if "pod" in mesh.shape else "data",
        "expert_cap": model,
        "groups": batch,
        "dstate": None,
        # Megatron-style sequence parallelism: the residual stream (and the
        # norms/adds on it) lives sequence-sharded on the model axis; TP
        # blocks gather on entry / reduce-scatter on exit.  Enabled by
        # sequence_parallel=True (beyond-paper optimization).
        "residual_seq": None,
    }

    if attn_mode == "seq":
        # seq-mode archs already live sequence-sharded: the residual
        # constraint must preserve that layout, not pin replication.
        act_rules["residual_seq"] = model
    if sequence_parallel and attn_mode == "head" and model is not None:
        act_rules["residual_seq"] = model
        notes.append("sequence-parallel residual stream (RS/AG instead of AR)")

    moe_groups = 1
    if cfg.moe is not None:
        # group size ~4K tokens bounds the [G,Tg,E,C] dispatch working set
        # (GShard sizing); groups stay a multiple of the batch shards.
        bsz = _axis_size(mesh, batch) if batch else 1
        tokens = wl.tokens // max(microbatches, 1)
        g = max(bsz, tokens // 4096)
        g = min(g, tokens)
        while g > bsz and (tokens % g or g % max(bsz, 1)):
            g -= 1
        moe_groups = max(g, 1)

    return ShardingPlan(mesh=mesh, param_rules=param_rules, act_rules=act_rules,
                        kv_repeat=kv_repeat, moe_groups=moe_groups,
                        attn_mode=attn_mode, notes=tuple(notes))


def zero1_rules(plan: ShardingPlan) -> ShardingPlan:
    """Optimizer-state plan: like params but with 'data' added to the
    replicated logical axes (ZeRO-1 partitioning of m/v/master weights)."""
    rules = dict(plan.param_rules)
    # shard the embedding/e.g. d_model dim of optimizer state over data
    rules["embed"] = "data"
    rules["layers"] = None
    out = ShardingPlan(mesh=plan.mesh, param_rules=rules,
                       act_rules=plan.act_rules, kv_repeat=plan.kv_repeat,
                       moe_groups=plan.moe_groups, attn_mode=plan.attn_mode,
                       notes=plan.notes + ("zero1",))
    return out
