"""Collective-communication helpers and overlap utilities.

GSPMD inserts the collectives; this module provides (a) einsum wrappers
whose sharding constraints steer XLA toward overlap-friendly schedules
(reduce-scatter instead of all-reduce, split-S decode attention), and
(b) analytic wire-cost models used by the roofline and the hillclimb
napkin math.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def tp_matmul_rs(x: jax.Array, w: jax.Array,
                 out_axes: Sequence[Optional[str]]) -> jax.Array:
    """Tensor-parallel matmul whose partial sums leave as a reduce-scatter
    (sequence-parallel exit) instead of an all-reduce: constrain the result
    to the sequence-sharded layout and GSPMD lowers psum -> reduce-scatter.

    x: [B, S, K/tp] (contracted dim sharded); w: [K/tp, M].
    """
    y = jnp.einsum("bsk,km->bsm", x, w)
    return constrain(y, tuple(out_axes))


@dataclass(frozen=True)
class WireCost:
    """Ring-algorithm wire bytes per device for a collective over n ranks."""
    n: int
    link_bw: float = 50e9
    links: int = 4

    def all_reduce(self, nbytes: float) -> float:
        return 2.0 * nbytes * (self.n - 1) / self.n

    def all_gather(self, out_bytes: float) -> float:
        return out_bytes * (self.n - 1) / self.n

    def reduce_scatter(self, in_bytes: float) -> float:
        return in_bytes * (self.n - 1) / self.n

    def all_to_all(self, in_bytes: float) -> float:
        return in_bytes * (self.n - 1) / self.n

    def time(self, wire_bytes: float) -> float:
        return wire_bytes / (self.links * self.link_bw)


def overlap_headroom(t_compute: float, t_collective: float) -> float:
    """Fraction of the collective time hidable behind compute (the
    latency-hiding scheduler budget): 1.0 = fully hidden."""
    if t_collective <= 0:
        return 1.0
    return min(1.0, t_compute / t_collective)


def grad_reduce_dtype_saving(param_bytes_f32: float, n_data: int,
                             compressed: bool = True) -> Tuple[float, float]:
    """Wire bytes of the DP gradient reduce-scatter with/without bf16
    gradient compression (the OptConfig.grad_dtype knob)."""
    wc = WireCost(n_data)
    full = wc.reduce_scatter(param_bytes_f32)
    comp = wc.reduce_scatter(param_bytes_f32 / 2)
    return full, comp if compressed else full
