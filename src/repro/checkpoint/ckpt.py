"""Fault-tolerant checkpointing.

* Atomic step directories (write to ``.tmp`` then rename) — a crash mid-save
  never corrupts the latest checkpoint.
* Mesh-agnostic restore: leaves are stored as full (global) arrays plus a
  tree manifest; ``restore`` re-shards onto *any* target sharding pytree —
  this is the elastic-scaling path (restart on a different pod count).
* ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
  writes to disk on a background thread, overlapping I/O with training.
* Retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _treedef_paths(tree) -> List[str]:
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
            for path, _ in jax.tree_util.tree_leaves_with_path(tree)]


def save(path: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final checkpoint dir."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "crc": {k: zlib.crc32(v.tobytes()) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(path, keep)
    return final


def _gc(path: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(path, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(path: str, target: Any, *, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding for
    elastic re-sharding onto the current mesh."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    keys = _treedef_paths(target)
    assert set(keys) == set(manifest["keys"]), (
        "checkpoint/tree structure mismatch: "
        f"{sorted(set(keys) ^ set(manifest['keys']))[:5]}")
    leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(keys))
    for key, sh in zip(keys, shard_leaves):
        arr = data[key]
        if verify and zlib.crc32(arr.tobytes()) != manifest["crc"][key]:
            raise IOError(f"checkpoint corruption detected in leaf {key}")
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk asynchronously."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        flat = _flatten(tree)          # device->host copy happens here
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            try:
                keys = _treedef_paths(tree)
                leaves = [flat[k] for k in keys]
                host_tree = jax.tree_util.tree_unflatten(treedef, leaves)
                save(self.path, step, host_tree, keep=self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
