from repro.checkpoint.ckpt import (  # noqa: F401
    AsyncCheckpointer, latest_step, restore, save,
)
