"""AdamW in pure JAX (pytree states), with gradient clipping and optional
gradient compression (bf16 accumulation/reduction — halves the wire bytes
of the data-parallel gradient reduce-scatter)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    grad_dtype: str = "bfloat16"     # gradient compression for the DP reduce
    state_dtype: str = "float32"     # m/v dtype (bf16 halves optimizer HBM)


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    sd = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sd)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: OptConfig, step) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    with jax.named_scope("optimizer"):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        lr = _schedule(cfg, state["step"])
        sd = jnp.dtype(cfg.state_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
            v1 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
            mh = m1 / (1 - cfg.b1 ** step)
            vh = v1 / (1 - cfg.b2 ** step)
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:   # no decay on norms/scalars/biases
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m1.astype(sd), v1.astype(sd))

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state["m"])
        flat_v = jax.tree_util.tree_leaves(state["v"])
        new = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tdef, [n[0] for n in new])
        new_m = jax.tree_util.tree_unflatten(tdef, [n[1] for n in new])
        new_v = jax.tree_util.tree_unflatten(tdef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
