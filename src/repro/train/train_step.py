"""Loss + jittable train step with microbatch gradient accumulation.

The step is built per (ModelConfig, ShardingPlan): GSPMD handles the DP
gradient reduction (out_shardings of the grads = ZeRO-1 optimizer layout ⇒
reduce-scatter), gradients are compressed to ``opt.grad_dtype`` before
accumulation, and each scanned layer-unit is rematerialized in backward.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.distributed.sharding import ShardingPlan, constrain
from repro.models.lm import lm_forward
from repro.train.optimizer import OptConfig, adamw_update


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Sharded-vocab-friendly CE: logsumexp is a plain reduction and the
    label pick is a masked sum — both partition over a model-sharded vocab
    dim (take_along_axis would make GSPMD all-gather the logits)."""
    with jax.named_scope("loss"):
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
        onehot = (vocab_iota[None, None, :] == labels[..., None])
        ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return jnp.mean(lse - ll)


def make_loss_fn(cfg: ModelConfig, plan: Optional[ShardingPlan] = None):
    kv_repeat = plan.kv_repeat if plan else 1
    moe_groups = plan.moe_groups if plan else 1

    def loss_fn(params, batch: Dict[str, jax.Array]) -> jax.Array:
        # cast the f32 masters to the compute dtype ONCE per step: the
        # layer scan then carries bf16 params and — crucially — the
        # backward scan's stacked gradient carry is bf16 too (halves the
        # dominant training buffer for the MoE giants).
        from repro.models.params import cast_tree
        params = cast_tree(params, jnp.dtype(cfg.compute_dtype))
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits = lm_forward(cfg, params, inputs, kv_repeat=kv_repeat,
                            moe_groups=moe_groups, train=True)
        labels = batch["labels"]
        if cfg.frontend == "vision" and "features" in inputs:
            # labels cover the full (patches + text) sequence
            pass
        if labels.shape[1] != logits.shape[1]:
            labels = labels[:, :logits.shape[1]]
        # next-token prediction for causal families; per-frame CE for encoders
        if cfg.family in ("encoder", "audio"):
            return cross_entropy(logits, labels, cfg.vocab_size)
        return cross_entropy(logits[:, :-1], labels[:, 1:], cfg.vocab_size)

    return loss_fn


def make_train_step(cfg: ModelConfig, opt: OptConfig,
                    plan: Optional[ShardingPlan] = None,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)."""
    loss_fn = make_loss_fn(cfg, plan)
    gdtype = jnp.dtype(opt.grad_dtype)

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        with jax.named_scope("grad_compress"):
            grads = jax.tree_util.tree_map(lambda g: g.astype(gdtype), grads)
        return loss, grads

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mbatch):
                loss_acc, gacc = carry
                loss, grads = grads_of(params, mbatch)
                gacc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), gacc, grads)
                return (loss_acc + loss, gacc), None

            # accumulate in the compressed grad dtype (bf16 has the range;
            # the f32 cast happens once inside the optimizer update)
            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, gdtype), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, gzero), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        new_params, new_state, om = adamw_update(params, grads, opt_state, opt)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    return train_step
