"""Training loop with fault tolerance.

Features (designed for 1000+ node operation, exercised here at CPU scale):
  * checkpoint/restart: atomic checkpoints via AsyncCheckpointer; restore
    resumes (params, optimizer, step) and the data stream is re-seeded per
    step so a restart replays identically;
  * elastic scaling: checkpoints store global arrays; restore() re-shards
    onto whatever mesh/plan the relaunched job built;
  * straggler mitigation: per-step wall-clock watchdog — steps slower than
    ``straggler_factor`` × the running median are logged and counted, the
    hook where a pod-level scheduler would trigger replacement;
  * overlap: async checkpoint I/O off the training thread; GSPMD overlaps
    the DP gradient reduce-scatter with backward compute.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from repro.core.config import ModelConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.lm import init_lm_params
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    microbatches: int = 1
    seed: int = 0


@dataclass
class TrainerState:
    step: int = 0
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    straggler_steps: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, opt: OptConfig, tcfg: TrainerConfig,
                 data: Optional[SyntheticLM] = None, plan=None,
                 batch_fn: Optional[Callable[[int], Dict[str, Any]]] = None,
                 seq_len: int = 128, global_batch: int = 8):
        self.cfg, self.opt, self.tcfg, self.plan = cfg, opt, tcfg, plan
        self.data = data or SyntheticLM(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=global_batch, seed=tcfg.seed))
        self.batch_fn = batch_fn or self.data.batch
        self.state = TrainerState()
        self.ckpt = (AsyncCheckpointer(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)
        key = jax.random.PRNGKey(tcfg.seed)
        self.params = init_lm_params(cfg, key)
        self.opt_state = init_opt_state(self.params, opt)
        self._step_fn = jax.jit(make_train_step(
            cfg, opt, plan, microbatches=tcfg.microbatches))

    # -- fault tolerance -----------------------------------------------------
    def maybe_restore(self) -> bool:
        if self.ckpt is None:
            return False
        step = latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        restored = restore(self.tcfg.ckpt_dir, tree, step=step)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.state.step = step
        return True

    def _checkpoint(self) -> None:
        if self.ckpt is not None:
            self.ckpt.save(self.state.step,
                           {"params": self.params, "opt": self.opt_state})

    # -- loop ------------------------------------------------------------------
    def run(self, log: Callable[[str], None] = print) -> TrainerState:
        t = self.state
        while t.step < self.tcfg.steps:
            t0 = time.perf_counter()     # full iteration: data + step
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.batch_fn(t.step).items()}
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            t.step += 1
            t.losses.append(loss)
            t.step_times.append(dt)
            med = float(np.median(t.step_times[-20:]))
            if len(t.step_times) > 5 and dt > self.tcfg.straggler_factor * med:
                t.straggler_steps += 1
                log(f"[straggler] step {t.step} took {dt:.2f}s "
                    f"(median {med:.2f}s) — would trigger replacement")
            if t.step % self.tcfg.log_every == 0:
                log(f"step {t.step:5d} loss {loss:.4f} "
                    f"({dt * 1e3:.0f} ms/step)")
            if self.tcfg.ckpt_every and t.step % self.tcfg.ckpt_every == 0:
                self._checkpoint()
        if self.ckpt is not None:
            self._checkpoint()
            self.ckpt.wait()
        return t
