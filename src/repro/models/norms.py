"""Normalization layers (pure functions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    with jax.named_scope("norm"):
        dt = x.dtype
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def gated_rms_norm(x: jax.Array, gate: jax.Array, scale: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    """Mamba2 gated RMSNorm: norm(x * silu(z)) with learned scale."""
    with jax.named_scope("ssm_gate"):
        dt = x.dtype
        xf = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMSNorm over head_dim (qwen3-style qk-norm). x: [..., H, hd]."""
    with jax.named_scope("norm"):
        dt = x.dtype
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)
