"""Mamba-1 block: selective scan (S6) with data-dependent dt/B/C.

Used by the paper-model suite (mamba-130m …) for the Fig. 7a reproduction.
The scan core is a chunked associative scan (jnp; tagged "ssm_core")."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SSMConfig
from repro.distributed.sharding import constrain
from repro.kernels.conv1d.ops import causal_conv1d
from repro.kernels.decode_fused.ops import mamba1_decode_fused
from repro.models.mamba2 import masked_conv_state
from repro.models.params import ParamDef


def dt_rank(d_model: int, s: SSMConfig) -> int:
    return s.dt_rank or max(1, math.ceil(d_model / 16))


def mamba1_param_defs(d_model: int, s: SSMConfig) -> Dict[str, ParamDef]:
    di = s.d_inner(d_model)
    dtr = dt_rank(d_model, s)
    return {
        "wx": ParamDef((d_model, di), ("embed", "conv_dim"), fan_in=d_model),
        "wz": ParamDef((d_model, di), ("embed", "conv_dim"), fan_in=d_model),
        "conv_w": ParamDef((di, s.conv_kernel), ("conv_dim", None),
                           fan_in=s.conv_kernel),
        "conv_b": ParamDef((di,), ("conv_dim",), init="zeros"),
        "x_proj": ParamDef((di, dtr + 2 * s.d_state), ("conv_dim", None),
                           fan_in=di),
        "dt_proj": ParamDef((dtr, di), ("dt_rank", "conv_dim"), fan_in=dtr),
        "dt_bias": ParamDef((di,), ("conv_dim",), init="dt_bias"),
        "A_log": ParamDef((di, s.d_state), ("conv_dim", "dstate"), init="a_log"),
        "D": ParamDef((di,), ("conv_dim",), init="ones"),
        "out_proj": ParamDef((di, d_model), ("conv_dim", "embed"),
                             init="normal_out", fan_in=di),
    }


def selective_scan(xs, dt, A, Bm, Cm, D, initial_state=None, chunk: int = 512):
    """xs: [B,S,di]; dt: [B,S,di]; A: [di,N]; Bm/Cm: [B,S,N]; D: [di].
    Returns (y [B,S,di], final h [B,di,N])."""
    b, s, di = xs.shape
    n = A.shape[-1]
    with jax.named_scope("ssm_core"):
        xf = xs.astype(jnp.float32)
        dtf = dt.astype(jnp.float32)
        dA = jnp.exp(dtf[..., None] * A[None, None])          # [B,S,di,N]
        dBx = (dtf * xf)[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
        pad = (-s) % chunk
        if pad:
            dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                         constant_values=1.0)
            dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nc = (s + pad) // chunk
        dA = dA.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
        dBx = dBx.reshape(b, nc, chunk, di, n).transpose(1, 0, 2, 3, 4)
        h0 = (jnp.zeros((b, di, n), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

        def combine(l, r):
            (a1, b1), (a2, b2) = l, r
            return a1 * a2, a2 * b1 + b2

        def chunk_step(h, inp):
            cdA, cdBx = inp                                   # [B,chunk,di,N]
            accA, accB = jax.lax.associative_scan(combine, (cdA, cdBx), axis=1)
            hs = accB + accA * h[:, None]
            return hs[:, -1], hs

        hT, hs = jax.lax.scan(chunk_step, h0, (dA, dBx))
        hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, di, n)[:, :s]
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cm.astype(jnp.float32))
        y = y + xf * D[None, None]
    return y.astype(xs.dtype), hT


def mamba1_block(p: Dict, x: jax.Array, s: SSMConfig, d_model: int, *,
                 cache: Optional[Dict] = None, eps: float = 1e-5,
                 mask: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """``mask`` ([B, S] bool, chunked prefill): invalid tokens are inert —
    dt is driven to zero so the scan state passes through unchanged, and
    the conv state is rebuilt from the trailing valid inputs."""
    di = s.d_inner(d_model)
    dtr = dt_rank(d_model, s)
    dt_ = x.dtype
    with jax.named_scope("ssm_in_proj"):
        xi = jnp.einsum("bsd,de->bse", x, p["wx"].astype(dt_))
        z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(dt_))
    xi = constrain(xi, ("batch", "seq", "conv_dim"))
    xi_in = xi
    init_conv = cache["conv"] if cache is not None else None
    xi, conv_state = causal_conv1d(xi, p["conv_w"], p["conv_b"],
                                   initial_state=init_conv)
    if cache is not None and mask is not None:
        conv_state = masked_conv_state(init_conv, xi_in, mask, s.conv_kernel)
    with jax.named_scope("ssm_in_proj"):
        proj = jnp.einsum("bse,ef->bsf", xi, p["x_proj"].astype(dt_))
        dt_low, bm, cm = (proj[..., :dtr], proj[..., dtr:dtr + s.d_state],
                          proj[..., dtr + s.d_state:])
        dt_pre = (jnp.einsum("bsr,re->bse", dt_low, p["dt_proj"].astype(dt_)
                             ).astype(jnp.float32)
                  + p["dt_bias"].astype(jnp.float32))
        if mask is not None:
            # -30 ⇒ softplus -> 0 ⇒ invalid tokens update no scan state
            dt_pre = jnp.where(mask[:, :, None], dt_pre, -30.0)
        dt = jax.nn.softplus(dt_pre)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    init_ssm = cache["ssm"] if cache is not None else None
    from repro.kernels import dispatch as _dispatch
    if _dispatch.get_backend() != "ref":
        from repro.kernels.scan1.ops import selective_scan_op
        y, ssm_state = selective_scan_op(xi, dt, A, bm, cm,
                                         p["D"].astype(jnp.float32),
                                         initial_state=init_ssm)
    else:
        y, ssm_state = selective_scan(xi, dt, A, bm, cm,
                                      p["D"].astype(jnp.float32), init_ssm)
    with jax.named_scope("ssm_gate"):
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_)
    with jax.named_scope("ssm_out_proj"):
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssm": ssm_state.astype(jnp.float32)}
    return constrain(out, ("batch", "seq", "embed")), new_cache


def mamba1_decode(p: Dict, x: jax.Array, s: SSMConfig, d_model: int, *,
                  cache: Dict, eps: float = 1e-5) -> Tuple[jax.Array, Dict]:
    """Single-token step; conv shift + dt/B/C projections + S6 state update
    run as one fused decode kernel."""
    dtr = dt_rank(d_model, s)
    dt_ = x.dtype
    xt = x[:, 0]
    with jax.named_scope("ssm_in_proj"):
        xi = xt @ p["wx"].astype(dt_)
        z = xt @ p["wz"].astype(dt_)
    y, conv_state, h = mamba1_decode_fused(
        cache["conv"], cache["ssm"], xi, p["conv_w"], p["conv_b"],
        p["x_proj"], p["dt_proj"], p["dt_bias"], p["A_log"], p["D"],
        d_state=s.d_state, dt_rank=dtr)
    with jax.named_scope("ssm_gate"):
        y = y * jax.nn.silu(z.astype(jnp.float32))
    with jax.named_scope("ssm_out_proj"):
        out = (y.astype(dt_) @ p["out_proj"].astype(dt_))[:, None, :]
    return out, {"conv": conv_state, "ssm": h}


def init_mamba1_cache(d_model: int, s: SSMConfig, batch: int,
                      dtype=jnp.bfloat16) -> Dict:
    di = s.d_inner(d_model)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, s.d_state), jnp.float32),
    }
