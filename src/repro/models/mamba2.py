"""Mamba-2 (SSD) block — in/out projections + conv1d + chunked SSD core."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SSMConfig
from repro.distributed.sharding import constrain
from repro.kernels.conv1d.ops import causal_conv1d
from repro.kernels.decode_fused.ops import mamba2_decode_fused
from repro.kernels.ssd.ops import ssd_chunked_raw
from repro.models.norms import gated_rms_norm
from repro.models.params import ParamDef


def mamba2_param_defs(d_model: int, s: SSMConfig) -> Dict[str, ParamDef]:
    di = s.d_inner(d_model)
    nh = s.n_ssm_heads(d_model)
    gn = s.n_groups * s.d_state
    conv_dim = di + 2 * gn
    return {
        "wz": ParamDef((d_model, di), ("embed", "conv_dim"), fan_in=d_model),
        "wxBC": ParamDef((d_model, conv_dim), ("embed", "conv_dim"), fan_in=d_model),
        "wdt": ParamDef((d_model, nh), ("embed", "ssm_heads"), fan_in=d_model),
        "conv_w": ParamDef((conv_dim, s.conv_kernel), ("conv_dim", None),
                           fan_in=s.conv_kernel),
        "conv_b": ParamDef((conv_dim,), ("conv_dim",), init="zeros"),
        "A_log": ParamDef((nh,), ("ssm_heads",), init="a_log"),
        "D": ParamDef((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="dt_bias"),
        "norm_scale": ParamDef((di,), ("conv_dim",), init="zeros"),
        "out_proj": ParamDef((di, d_model), ("conv_dim", "embed"),
                             init="normal_out", fan_in=di),
    }


def masked_conv_state(init_state: Optional[jax.Array], x_in: jax.Array,
                      mask: jax.Array, k: int) -> jax.Array:
    """Conv state after a ragged chunk: the trailing ``k-1`` *valid* inputs
    per row.  Valid tokens are a left-aligned prefix of the chunk (length
    ``mask.sum(1)``), so the window ends at that length, not at the padded
    chunk end.  x_in: [B, S, C] pre-conv inputs; mask: [B, S] bool."""
    b, _, c = x_in.shape
    if k <= 1:
        return jnp.zeros((b, 0, c), x_in.dtype)
    if init_state is None:
        init_state = jnp.zeros((b, k - 1, c), x_in.dtype)
    src = jnp.concatenate([init_state.astype(x_in.dtype), x_in], axis=1)
    lens = jnp.sum(mask, axis=1).astype(jnp.int32)
    return jax.vmap(
        lambda row, l: jax.lax.dynamic_slice_in_dim(row, l, k - 1, axis=0)
    )(src, lens)


def _split_xbc(xbc: jax.Array, s: SSMConfig, d_model: int):
    di = s.d_inner(d_model)
    gn = s.n_groups * s.d_state
    xs = xbc[..., :di]
    bm = xbc[..., di:di + gn]
    cm = xbc[..., di + gn:]
    lead = xbc.shape[:-1]
    return (xs, bm.reshape(*lead, s.n_groups, s.d_state),
            cm.reshape(*lead, s.n_groups, s.d_state))


def mamba2_block(p: Dict, x: jax.Array, s: SSMConfig, d_model: int, *,
                 cache: Optional[Dict] = None, eps: float = 1e-5,
                 mask: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Optional[Dict]]:
    """Full-sequence pass. If cache is given (prefill), returns final states.

    ``mask`` ([B, S] bool, chunked prefill): rows whose valid tokens are a
    left-aligned prefix.  Invalid tokens are inert — their dt is driven to
    zero (state passes through unchanged) and the conv state is rebuilt
    from the trailing *valid* inputs, so the returned states equal those of
    a prefill over only the valid prefix."""
    b, seq, _ = x.shape
    di = s.d_inner(d_model)
    nh = s.n_ssm_heads(d_model)
    dt_ = x.dtype
    with jax.named_scope("ssm_in_proj"):
        z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(dt_))
        xbc = jnp.einsum("bsd,de->bse", x, p["wxBC"].astype(dt_))
        dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(dt_))
    xbc = constrain(xbc, ("batch", "seq", "conv_dim"))
    if mask is not None:
        # -30 ⇒ softplus -> 0 ⇒ invalid tokens update no SSM state
        dt_raw = jnp.where(mask[:, :, None], dt_raw, -30.0)
    xbc_in = xbc
    init_conv = cache["conv"] if cache is not None else None
    xbc, conv_state = causal_conv1d(xbc, p["conv_w"], p["conv_b"],
                                    initial_state=init_conv)
    if cache is not None and mask is not None:
        conv_state = masked_conv_state(init_conv, xbc_in, mask, s.conv_kernel)
    xs, bm, cm = _split_xbc(xbc, s, d_model)
    xh = constrain(xs.reshape(b, seq, nh, s.headdim),
                   ("batch", "seq", "ssm_heads", None))

    # pad sequence to a chunk multiple; padded dt_raw = -inf ⇒ softplus->0
    # ⇒ padded tokens are inert
    pad = (-seq) % s.chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_raw = jnp.pad(dt_raw, ((0, 0), (0, pad), (0, 0)),
                         constant_values=-30.0)
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    init_ssm = cache["ssm"] if cache is not None else None
    y, ssm_state = ssd_chunked_raw(xh, dt_raw, p["dt_bias"], p["A_log"],
                                   bm, cm, p["D"], chunk=s.chunk,
                                   initial_state=init_ssm)
    y = y[:, :seq].reshape(b, seq, di)
    y = constrain(y, ("batch", "seq", "conv_dim"))
    y = gated_rms_norm(y, z, p["norm_scale"], eps)
    with jax.named_scope("ssm_out_proj"):
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    out = constrain(out, ("batch", "seq", "embed"))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssm": ssm_state.astype(cache["ssm"].dtype)}
    return out, new_cache


def mamba2_decode(p: Dict, x: jax.Array, s: SSMConfig, d_model: int, *,
                  cache: Dict, eps: float = 1e-5) -> Tuple[jax.Array, Dict]:
    """Single-token step. x: [B, 1, D]; cache: {"conv": [B,K-1,C], "ssm": [B,H,P,N]}.
    Conv shift + state update run as one fused decode kernel."""
    b = x.shape[0]
    di = s.d_inner(d_model)
    dt_ = x.dtype
    xt = x[:, 0]
    with jax.named_scope("ssm_in_proj"):
        z = xt @ p["wz"].astype(dt_)
        xbc = xt @ p["wxBC"].astype(dt_)
        dt_raw = xt @ p["wdt"].astype(dt_)
    y, conv_state, ssm_state = mamba2_decode_fused(
        cache["conv"], cache["ssm"], xbc, p["conv_w"], p["conv_b"],
        dt_raw, p["dt_bias"], p["A_log"], p["D"],
        n_groups=s.n_groups, d_state=s.d_state, headdim=s.headdim)
    y = y.reshape(b, di)
    y = gated_rms_norm(y[:, None, :], z[:, None, :], p["norm_scale"], eps)[:, 0]
    with jax.named_scope("ssm_out_proj"):
        out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    return out, {"conv": conv_state.astype(cache["conv"].dtype),
                 "ssm": ssm_state.astype(cache["ssm"].dtype)}


def init_mamba2_cache(d_model: int, s: SSMConfig, batch: int,
                      dtype=jnp.bfloat16) -> Dict:
    di = s.d_inner(d_model)
    nh = s.n_ssm_heads(d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.headdim, s.d_state), jnp.float32),
    }
