"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.params import ParamDef


def mlp_param_defs(d_model: int, d_ff: int) -> Dict[str, ParamDef]:
    return {
        "wi": ParamDef((d_model, d_ff), ("embed", "ff"), fan_in=d_model),
        "wg": ParamDef((d_model, d_ff), ("embed", "ff"), fan_in=d_model),
        "wo": ParamDef((d_ff, d_model), ("ff", "embed"), init="normal_out",
                       fan_in=d_ff),
    }


def mlp(p: Dict, x: jax.Array, act: str = "silu") -> jax.Array:
    with jax.named_scope("mlp"):
        dt = x.dtype
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        actf = jax.nn.silu if act == "silu" else jax.nn.gelu
        h = constrain(actf(g) * h, ("batch", "seq", "ff"))
        return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
