"""Rotary position embeddings."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rope_tables(seq_len: int, head_dim: int, theta: float = 10_000.0,
                dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """(sin, cos) tables of shape [seq_len, head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)
    return jnp.sin(ang).astype(dtype), jnp.cos(ang).astype(dtype)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: [B, S, H, hd]; sin/cos: [S, hd//2] (or [B, S, hd//2] for decode)."""
    with jax.named_scope("rope"):
        half = x.shape[-1] // 2
        x1, x2 = x[..., :half], x[..., half:]
        if sin.ndim == 2:      # [S, half] -> broadcast over batch & heads
            s = sin[None, :, None, :]
            c = cos[None, :, None, :]
        else:                  # [B, S, half] (gathered at decode positions)
            s = sin[:, :, None, :]
            c = cos[:, :, None, :]
        s, c = s.astype(x.dtype), c.astype(x.dtype)
        return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
