"""Parameter definition / initialization utilities.

Params are plain nested dicts of jnp arrays.  Structure is described by a
parallel pytree of :class:`ParamDef` (shape + logical axes + initializer),
from which we derive both the initialized values and the sharding specs —
one source of truth, no drift between init and partitioning.
"""
from __future__ import annotations

import math
import zlib
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names (None = replicated dim)
    init: str = "normal"              # normal | zeros | ones | a_log | dt_bias | normal_out
    fan_in: Optional[int] = None      # override fan-in for "normal"
    scale: float = 1.0


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_defs_map(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=is_def)


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layers dim to every ParamDef in the tree."""
    def _stack(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.fan_in, d.scale)
    return tree_defs_map(_stack, defs)


def _init_leaf(key: jax.Array, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "a_log":
        # Mamba: A in [1, 16], stored as log.  Uniform over the range.
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if d.init == "dt_bias":
        # Inverse softplus of dt ~ LogUniform[1e-3, 1e-1].
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if d.init in ("normal", "normal_out"):
        fan_in = d.fan_in
        if fan_in is None:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(fan_in, 1))
        if d.init == "normal_out":
            std = std / 2.0
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {d.init!r}")


def init_params(defs, key: jax.Array, dtype=jnp.float32):
    """Initialize a param pytree from its defs; keys derived from tree paths.

    The per-leaf fold-in constant must be a STABLE hash of the path:
    Python's ``hash(str)`` is salted per process (PYTHONHASHSEED), which
    made every fresh interpreter draw different "seeded" params — the
    repo's bit-exact greedy parity tests became a per-invocation lottery
    over argmax near-ties.  crc32 is process-independent."""
    leaves = jax.tree_util.tree_leaves_with_path(defs, is_leaf=is_def)

    def path_str(path) -> str:
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)

    out = {}
    for path, d in leaves:
        k = jax.random.fold_in(key, np.uint32(
            zlib.crc32(path_str(path).encode()) & 0x7FFFFFFF))
        out[path_str(path)] = _init_leaf(k, d, dtype)

    # Rebuild nested structure.
    flat_defs = {path_str(p): d for p, d in leaves}
    assert set(flat_defs) == set(out)
    treedef = jax.tree_util.tree_structure(defs, is_leaf=is_def)
    ordered = [out[path_str(p)] for p, _ in leaves]
    return jax.tree_util.tree_unflatten(treedef, ordered)


def param_axes(defs):
    """Pytree of logical-axis tuples, same structure as the params."""
    return tree_defs_map(lambda d: d.axes, defs)


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def cast_tree(params, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
