"""Mixture-of-Experts feed-forward.

Baseline impl is GShard-style einsum dispatch/combine with a capacity factor:
it is fully GSPMD-partitionable (experts over the EP axis, expert d_ff over
the TP axis; the token→expert exchange lowers to all-to-all style
collectives).  A sort-based `ragged` path exists for single-shard execution
and as the beyond-paper optimization target.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.config import MoEConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamDef


def moe_param_defs(d_model: int, m: MoEConfig) -> Dict[str, ParamDef]:
    e, f = m.n_experts, m.d_ff_expert
    defs = {
        "router": ParamDef((d_model, e), ("embed", None), fan_in=d_model),
        "wi": ParamDef((e, d_model, f), ("experts", "embed", "expert_ff"),
                       fan_in=d_model),
        "wg": ParamDef((e, d_model, f), ("experts", "embed", "expert_ff"),
                       fan_in=d_model),
        "wo": ParamDef((e, f, d_model), ("experts", "expert_ff", "embed"),
                       init="normal_out", fan_in=f),
    }
    if m.shared_expert:
        defs["shared_wi"] = ParamDef((d_model, f), ("embed", "ff"), fan_in=d_model)
        defs["shared_wg"] = ParamDef((d_model, f), ("embed", "ff"), fan_in=d_model)
        defs["shared_wo"] = ParamDef((f, d_model), ("ff", "embed"),
                                     init="normal_out", fan_in=f)
    return defs


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * m.experts_per_token / m.n_experts
                  * m.capacity_factor)
    if c >= 16:
        return -(-c // 16) * 16   # pad to 16: capacity dim is TP-shardable
    return max(8, -(-c // 8) * 8)


def _router(p, x, m: MoEConfig):
    with jax.named_scope("moe_route"):
        logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                            p["router"].astype(jnp.float32))
        gates, idx = jax.lax.top_k(logits, m.experts_per_token)
        gates = jax.nn.softmax(gates, axis=-1)
        return gates, idx


def moe_gshard(p: Dict, x: jax.Array, m: MoEConfig, n_groups: int,
               act: str = "silu") -> jax.Array:
    """x: [B, S, D]. Tokens are reshaped into n_groups dispatch groups
    aligned with the data shards."""
    b, s, d = x.shape
    t = b * s
    g = min(n_groups, t)
    while t % g:
        g -= 1
    tg = t // g
    xg = x.reshape(g, tg, d)
    xg = constrain(xg, ("groups", None, "embed"))
    cap = _capacity(tg, m)

    gates, idx = _router(p, xg, m)                      # [g,tg,k]
    with jax.named_scope("moe_dispatch"):
        e = m.n_experts
        k = m.experts_per_token
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)   # [g,tg,k,e]
        # position of each (token, expert-choice) in its expert's buffer
        pos = jnp.cumsum(onehot.reshape(g, tg * k, e),
                         axis=1).reshape(g, tg, k, e) - 1.0
        # contract the expert dim per choice slot — never materialize the
        # [g,t,k,e,cap] outer product (it is E×k×cap per token!)
        pos_k = jnp.sum(pos * onehot, axis=-1)               # [g,tg,k]
        keep_k = pos_k < cap                                 # capacity drop
        capslot = jax.nn.one_hot(pos_k.astype(jnp.int32), cap,
                                 dtype=jnp.float32)          # [g,tg,k,cap]
        weighted = onehot * (gates * keep_k)[..., None]      # [g,tg,k,e]
        combine = jnp.einsum("gtke,gtkc->gtec", weighted, capslot)
        # token-major tensors: groups on data, experts on model
        combine = constrain(combine, ("groups", None, "experts", None))
        dispatch = (combine > 0).astype(x.dtype)
        ex_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
        # compute in the natural token-major layout first (groups stay on
        # data — no token gather), THEN reshard to expert-major: the
        # (groups:data, experts:model) -> (experts:data) transition IS the
        # EP all-to-all; capacity rows TP-shard on model.
        ex_in = constrain(ex_in, ("groups", "experts", None, None))
        ex_in = constrain(ex_in, (None, "experts_ep", "expert_cap", None))
    with jax.named_scope("moe_expert"):
        dt = x.dtype
        h = jnp.einsum("gecd,edf->gecf", ex_in, p["wi"].astype(dt))
        hg = jnp.einsum("gecd,edf->gecf", ex_in, p["wg"].astype(dt))
        actf = jax.nn.silu if act == "silu" else jax.nn.gelu
        h = constrain(actf(hg) * h, (None, "experts_ep", None, "expert_ff"))
        ex_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
        ex_out = constrain(ex_out, (None, "experts_ep", "expert_cap", None))
        # reverse all-to-all: back to token-major before the combine
        ex_out = constrain(ex_out, ("groups", "experts", None, None))
    with jax.named_scope("moe_combine"):
        y = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), ex_out)
    y = y.reshape(b, s, d)
    if m.shared_expert:
        with jax.named_scope("moe_shared_expert"):
            h = jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(x.dtype))
            hg = jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(x.dtype))
            actf = jax.nn.silu if act == "silu" else jax.nn.gelu
            y = y + jnp.einsum("bsf,fd->bsd", actf(hg) * h,
                               p["shared_wo"].astype(x.dtype))
    return constrain(y, ("batch", "seq", "embed"))


def moe_ragged(p: Dict, x: jax.Array, m: MoEConfig, act: str = "silu") -> jax.Array:
    """Sort-based MoE: flatten, sort by expert, grouped matmul, unsort.
    No capacity drop. Single-shard semantics (use inside shard_map or on one
    device); the beyond-paper optimized dispatch path."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gates, idx = _router(p, xf[None], m)
    gates, idx = gates[0], idx[0]                      # [t,k]
    k, e = m.experts_per_token, m.n_experts
    flat_idx = idx.reshape(-1)                         # [t*k]
    order = jnp.argsort(flat_idx)
    tok_of = order // k
    xs = xf[tok_of]                                    # [t*k, d] sorted by expert
    counts = jnp.bincount(flat_idx, length=e)
    with jax.named_scope("moe_expert"):
        dt = x.dtype
        h = jax.lax.ragged_dot(xs, p["wi"].astype(dt), counts.astype(jnp.int32))
        hg = jax.lax.ragged_dot(xs, p["wg"].astype(dt), counts.astype(jnp.int32))
        actf = jax.nn.silu if act == "silu" else jax.nn.gelu
        o = jax.lax.ragged_dot(actf(hg) * h, p["wo"].astype(dt),
                               counts.astype(jnp.int32))
    with jax.named_scope("moe_combine"):
        wsorted = gates.reshape(-1)[order]
        y = jax.ops.segment_sum(o * wsorted[:, None].astype(o.dtype), tok_of,
                                num_segments=t)
    y = y.reshape(b, s, d).astype(x.dtype)
    if m.shared_expert:
        with jax.named_scope("moe_shared_expert"):
            h = jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(x.dtype))
            hg = jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(x.dtype))
            actf = jax.nn.silu if act == "silu" else jax.nn.gelu
            y = y + jnp.einsum("bsf,fd->bsd", actf(hg) * h,
                               p["shared_wo"].astype(x.dtype))
    return y


def moe(p: Dict, x: jax.Array, m: MoEConfig, n_groups: int = 1,
        act: str = "silu") -> jax.Array:
    if m.impl == "ragged":
        return moe_ragged(p, x, m, act)
    return moe_gshard(p, x, m, n_groups, act)
