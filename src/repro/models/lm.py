"""Unified language model: embedding -> scanned layer segments -> head.

One code path serves every assigned architecture: dense / local:global /
MoE / SSM / hybrid / encoder-only / modality-stub models, selected purely by
``ModelConfig``.  Layers are grouped into repeating units and executed with
``lax.scan`` over stacked params (compact HLO; trip counts recoverable by the
HLO cost analyzer).

Decode API
----------
The cache built by :func:`init_lm_cache` carries a per-row position vector
``pos: [B] int32`` so every batch slot decodes at its own offset (the
serving engine admits requests at different times).  Three entry points:

* :func:`lm_prefill` — process the prompt, fill the cache.
* :func:`lm_prefill_chunk` — one chunk of a state-carrying chunked prefill:
  attention KV lands at the per-row running offset ``cache["pos"]`` (offset
  causal mask), SSM layers continue from their carried conv/SSM states, and
  a per-row ``lengths`` vector makes ragged/heterogeneous chunks inert past
  each row's valid prefix.  The serving layer (``repro.serving.prefill``)
  drives it to prefill arbitrarily long prompts at flat memory.
* :func:`lm_decode_step` — one token for all rows (``token: [B, 1]``).
* :func:`decode_tokens` — the fused multi-token loop: runs ``n`` greedy (or
  temperature-sampled) steps inside a single ``jax.lax.scan`` with on-device
  token selection, so a whole generation burst is one compiled program with
  zero host round-trips per token.  This is the serving fast path.

Mamba decode steps route through the fused conv-shift + state-update
kernels in ``repro.kernels.decode_fused`` (backend selected by
``REPRO_KERNEL_BACKEND`` / ``repro.kernels.dispatch``).

:func:`lm_prefill_chunk` and :func:`decode_tokens` accept a static
``kv_bucket``: the KV caches are sliced to that extent around the compiled
body so attention FLOPs/IO track the live prefix instead of ``max_seq``
(bit-identical outputs; see ``repro.serving.bucketing`` for how callers
pick the bucket from a bounded power-of-two ladder whose top rung is the
model's largest KV extent — the sliding *window* for rolling
architectures).  Rolling ("local") layers chunk-prefill through their
ring-buffer caches (modular scatter + ring-unrolling mask in
``repro.models.attention``); both entry points take a static ``rope_len``
so rope tables cover positions past a window-sized cache."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import blocks
from repro.models.norms import rms_norm
from repro.models.params import ParamDef, init_params, param_axes, stack_defs
from repro.models.rope import rope_tables


# --------------------------------------------------------------------------
# parameter / cache construction
# --------------------------------------------------------------------------

def model_param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.padded_vocab
    defs: Dict[str, Any] = {
        "embed": ParamDef((V, D), ("vocab", "embed"), fan_in=1, scale=0.02),
        "final_norm": ParamDef((D,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((D, V), ("embed", "vocab"), fan_in=D)
    segs = []
    for unit, n_rep in cfg.segments():
        unit_defs = tuple(blocks.layer_param_defs(cfg, kind) for kind in unit)
        segs.append(stack_defs(unit_defs, n_rep))
    defs["segments"] = segs
    if any(k == "mamba2+shared" for k in cfg.layer_kinds):
        defs["shared"] = blocks.shared_block_defs(cfg)
    if cfg.frontend != "none":
        defs["frontend_proj"] = ParamDef((cfg.frontend_feature_dim, D),
                                         (None, "embed"),
                                         fan_in=cfg.frontend_feature_dim)
    return defs


def init_lm_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return init_params(model_param_defs(cfg), key, dtype)


def lm_param_axes(cfg: ModelConfig):
    return param_axes(model_param_defs(cfg))


def init_lm_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                  kv_repeat: int = 1, shared_kv_repeat: int = 1,
                  dtype=jnp.bfloat16):
    segs = []
    for unit, n_rep in cfg.segments():
        unit_cache = tuple(
            blocks.init_layer_cache(cfg, kind, batch, max_seq,
                                    kv_repeat=kv_repeat,
                                    shared_kv_repeat=shared_kv_repeat,
                                    dtype=dtype)
            for kind in unit)
        segs.append(jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_rep,) + x.shape), unit_cache))
    return {"segments": segs, "pos": jnp.zeros((batch,), jnp.int32)}


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, inputs: Dict[str, jax.Array]) -> jax.Array:
    with jax.named_scope("embed"):
        if cfg.frontend == "audio":
            # input is precomputed frame features [B, S, feat]
            x = jnp.einsum("bsf,fd->bsd",
                           inputs["features"].astype(jnp.dtype(cfg.compute_dtype)),
                           params["frontend_proj"].astype(
                               jnp.dtype(cfg.compute_dtype)))
        else:
            emb = params["embed"]
            x = jnp.take(emb, inputs["tokens"], axis=0)
            x = x.astype(jnp.dtype(cfg.compute_dtype))
            if cfg.frontend == "vision" and "features" in inputs:
                feats = jnp.einsum(
                    "bnf,fd->bnd",
                    inputs["features"].astype(jnp.dtype(cfg.compute_dtype)),
                    params["frontend_proj"].astype(jnp.dtype(cfg.compute_dtype)))
                x = jnp.concatenate([feats, x], axis=1)
    return constrain(x, ("batch", "seq", "embed"))


def _head(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    with jax.named_scope("lm_head"):
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x,
                                params["embed"].astype(x.dtype))
        else:
            logits = jnp.einsum("bsd,dv->bsv", x,
                                params["lm_head"].astype(x.dtype))
        if cfg.padded_vocab != cfg.vocab_size:
            mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
            logits = jnp.where(mask[None, None, :], logits, -1e30)
    return constrain(logits, ("batch", "seq", "vocab"))


def _rope_for(cfg: ModelConfig, max_seq: int):
    if cfg.attn is None and cfg.shared_attn is None:
        return None, None
    a = cfg.attn or cfg.shared_attn
    rope = rope_tables(max_seq, a.head_dim, a.rope_theta)
    rope_local = None
    if cfg.attn is not None and cfg.attn.sliding_window is not None:
        rope_local = rope_tables(max_seq, a.head_dim, 10_000.0)
    return rope, rope_local


def _run_segments(cfg: ModelConfig, params, x: jax.Array, *, cache=None,
                  pos=None, kv_repeat=1, shared_kv_repeat=1, moe_groups=1,
                  rope=None, rope_local=None, train: bool = False,
                  chunk_mask=None):
    shared = params.get("shared")
    new_cache_segs = []
    for si, (unit, n_rep) in enumerate(cfg.segments()):
        seg_params = params["segments"][si]
        seg_cache = cache["segments"][si] if cache is not None else None

        def unit_body(x, xs, unit=unit):
            layer_p, layer_c = xs
            new_cs = []
            for li, kind in enumerate(unit):
                c = layer_c[li] if layer_c is not None else None
                x, nc = blocks.apply_layer(
                    cfg, kind, layer_p[li], x, rope=rope,
                    rope_local=rope_local, cache=c, pos=pos,
                    kv_repeat=kv_repeat, shared=shared,
                    shared_kv_repeat=shared_kv_repeat, moe_groups=moe_groups,
                    chunk_mask=chunk_mask)
                new_cs.append(nc if nc is not None else
                              (dict() if c is None else c))
            return x, tuple(new_cs)

        body = unit_body
        if train and cfg.remat == "block":
            body = jax.checkpoint(unit_body)

        def scan_body(x, xs):
            return body(x, xs)

        if cfg.scan_layers and n_rep > 1:
            x, new_seg_cache = jax.lax.scan(
                scan_body, x, (seg_params, seg_cache))
        else:
            ncs = []
            for r in range(n_rep):
                p_r = jax.tree_util.tree_map(lambda t: t[r], seg_params)
                c_r = (jax.tree_util.tree_map(lambda t: t[r], seg_cache)
                       if seg_cache is not None else None)
                x, nc = body(x, (p_r, c_r))
                ncs.append(nc)
            new_seg_cache = (jax.tree_util.tree_map(
                lambda *ts: jnp.stack(ts), *ncs) if cache is not None else None)
        new_cache_segs.append(new_seg_cache)
    return x, new_cache_segs


def lm_forward(cfg: ModelConfig, params, inputs: Dict[str, jax.Array], *,
               kv_repeat: int = 1, shared_kv_repeat: int = 1,
               moe_groups: int = 1, train: bool = True) -> jax.Array:
    """Full-sequence forward (training / encoder inference). Returns logits."""
    x = _embed(cfg, params, inputs)
    rope, rope_local = _rope_for(cfg, x.shape[1])
    x, _ = _run_segments(cfg, params, x, kv_repeat=kv_repeat,
                         shared_kv_repeat=shared_kv_repeat,
                         moe_groups=moe_groups, rope=rope,
                         rope_local=rope_local, train=train)
    return _head(cfg, params, x)


def lm_prefill(cfg: ModelConfig, params, inputs: Dict[str, jax.Array], cache,
               *, kv_repeat: int = 1, shared_kv_repeat: int = 1,
               moe_groups: int = 1) -> Tuple[jax.Array, Any]:
    """Process the prompt, fill the cache. Returns (last-token logits, cache)."""
    x = _embed(cfg, params, inputs)
    seq = x.shape[1]
    max_seq = _cache_max_seq(cfg, cache) or seq
    rope, rope_local = _rope_for(cfg, max(seq, max_seq))
    x, new_segs = _run_segments(cfg, params, x, cache=cache, pos=None,
                                kv_repeat=kv_repeat,
                                shared_kv_repeat=shared_kv_repeat,
                                moe_groups=moe_groups, rope=rope,
                                rope_local=rope_local, train=False)
    logits = _head(cfg, params, x[:, -1:])
    return logits, {"segments": new_segs,
                    "pos": jnp.full((x.shape[0],), seq, jnp.int32)}


def lm_prefill_chunk(cfg: ModelConfig, params, inputs: Dict[str, jax.Array],
                     cache, *, lengths: Optional[jax.Array] = None,
                     kv_repeat: int = 1, shared_kv_repeat: int = 1,
                     moe_groups: int = 1,
                     kv_bucket: Optional[int] = None,
                     rope_len: Optional[int] = None,
                     with_sentinel: bool = False):
    """One state-carrying prefill chunk: process ``S`` prompt tokens
    starting at each row's running offset ``cache["pos"]``.

    Attention layers scatter the chunk's KV at that offset and attend with
    the offset causal mask over the whole cache; SSM layers continue from
    their carried conv/SSM states.  ``lengths`` ([B] int32, default all-S)
    marks how many leading tokens of the chunk are valid per row — ragged
    last chunks and already-finished rows (length 0) are inert: they update
    no SSM state, and their stale KV is either overwritten by later writes
    or hidden by the decode-time ``valid_len`` mask.  Running the chunks of
    a prompt in order therefore reproduces :func:`lm_prefill` exactly (up
    to fp tolerance) with peak activation memory O(chunk), not O(prompt).

    ``kv_bucket`` (static int, or None for the full cache) bounds attention
    to the live prefix: KV-cache leaves larger than the bucket are sliced
    to their first ``kv_bucket`` rows before the flash kernels run and
    written back after, so the chunk's attention FLOPs/IO scale with the
    true prefix rather than ``max_seq``.  The caller must pick
    ``kv_bucket >= max(pos) + chunk`` capped at the model's KV extent (see
    ``repro.serving.bucketing``: the ladder tops out at the largest leaf,
    which for rolling architectures is the window); outputs are
    bit-identical to the unbucketed program.  Rolling ring-buffer leaves
    are only ever sliced while the live prefix hasn't wrapped (the caller
    contract above guarantees it); ``REPRO_RING_BUCKETS=0`` keeps them at
    the full window regardless.

    ``rope_len`` (static int, or None) sizes the rope tables.  Rolling
    architectures need it: their largest cache leaf is the *window*, but
    chunk positions run up to the full prompt length — the serving layer
    passes its ``max_seq``.  Values at a given position are identical for
    any sufficient table size.

    ``with_sentinel`` (static bool) appends a per-row divergence sentinel
    to the return: ``ok [B] bool`` is True iff every hidden state of the
    row's *valid* chunk tokens (and its emitted logits) is finite.  The
    reduction is fused into the chunk program — no extra dispatch or
    host sync — and costs O(B*S*D) compares next to the chunk's matmuls.

    Returns ``(logits of each row's last valid chunk token [B,1,V],
    updated cache)`` — plus ``ok`` when ``with_sentinel`` — with ``pos``
    advanced by ``lengths``."""
    _check_kv_bucket(cfg, kv_bucket)
    full_cache = cache
    if kv_bucket is not None:
        cache = _slice_kv_cache(cache, kv_bucket,
                                keep_extent=_ring_slice_exempt(cfg))
    x = _embed(cfg, params, inputs)
    b, s = x.shape[0], x.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), (b,))
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    else:
        lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
    chunk_mask = jnp.arange(s)[None, :] < lengths[:, None]
    max_seq = _cache_max_seq(cfg, cache) or s
    rope, rope_local = _rope_for(cfg, max(s, max_seq, rope_len or 0))
    x, new_segs = _run_segments(cfg, params, x, cache=cache, pos=pos,
                                kv_repeat=kv_repeat,
                                shared_kv_repeat=shared_kv_repeat,
                                moe_groups=moe_groups, rope=rope,
                                rope_local=rope_local, train=False,
                                chunk_mask=chunk_mask)
    last = jnp.clip(lengths - 1, 0, s - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)
    logits = _head(cfg, params, x_last)
    new_cache = {"segments": new_segs, "pos": pos + lengths}
    if kv_bucket is not None:
        new_cache = _unslice_kv_cache(full_cache, new_cache)
    if not with_sentinel:
        return logits, new_cache
    # divergence sentinel: a row is ok iff all its VALID chunk tokens'
    # hidden states and its emitted logits are finite (padding rows and
    # zero-length rows pass vacuously — their garbage is inert by design)
    ok = jnp.all(jnp.where(chunk_mask[:, :, None], jnp.isfinite(x), True),
                 axis=(1, 2))
    ok &= jnp.all(jnp.isfinite(logits[:, 0, :cfg.vocab_size]), axis=-1)
    ok |= lengths == 0
    return logits, new_cache, ok


def lm_decode_step(cfg: ModelConfig, params, token: jax.Array, cache, *,
                   kv_repeat: int = 1, shared_kv_repeat: int = 1,
                   moe_groups: int = 1,
                   rope_len: Optional[int] = None) -> Tuple[jax.Array, Any]:
    """One token step. token: [B, 1] int32 (or features [B,1,feat]).
    ``cache["pos"]`` is a [B] vector: rows may sit at different offsets.
    ``rope_len`` (static) sizes the rope tables past the cache extent —
    required for rolling-window architectures whose positions outgrow
    their window-sized caches (the serving layer passes ``max_seq``)."""
    pos = cache["pos"]
    inputs = {"tokens": token} if token.ndim == 2 else {"features": token}
    x = _embed(cfg, params, inputs)
    max_seq = _cache_max_seq(cfg, cache) or 1
    rope, rope_local = _rope_for(cfg, max(max_seq, rope_len or 0))
    x, new_segs = _run_segments(cfg, params, x, cache=cache, pos=pos,
                                kv_repeat=kv_repeat,
                                shared_kv_repeat=shared_kv_repeat,
                                moe_groups=moe_groups, rope=rope,
                                rope_local=rope_local, train=False)
    logits = _head(cfg, params, x)
    return logits, {"segments": new_segs, "pos": pos + 1}


def decode_tokens(cfg: ModelConfig, params, cache, first_token: jax.Array,
                  n: int, *, kv_repeat: int = 1, shared_kv_repeat: int = 1,
                  moe_groups: int = 1, temperature: float = 0.0,
                  rng: Optional[jax.Array] = None,
                  kv_bucket: Optional[int] = None,
                  rope_len: Optional[int] = None,
                  with_sentinel: bool = False):
    """Fused multi-token decode: run ``n`` generation steps inside one
    ``jax.lax.scan``.

    ``first_token`` ([B, 1] int32) is fed to the first step; every
    subsequent input token is selected on device (greedy argmax, or
    categorical sampling when ``temperature > 0`` with ``rng``), so the
    whole burst compiles to a single program with no host synchronisation
    per token.  Returns ``(tokens [B, n] int32, cache)`` — token ``[:, i]``
    is the model's output after consuming the (i-1)-th emitted token,
    exactly matching ``n`` sequential :func:`lm_decode_step` calls.

    ``kv_bucket`` (static int >= ``max(live pos) + n``, or None) slices the
    KV caches to the live prefix ONCE outside the scan, runs the whole
    burst against the slice, and writes it back once at the end — decode
    attention reads ``kv_bucket`` rows per token instead of ``max_seq``,
    bit-identically (rows of retired slots whose ``pos`` exceeds the bucket
    write nothing and produce finite garbage, as on the full-cache path).

    ``with_sentinel`` (static bool) appends a per-row divergence sentinel:
    ``ok [B] bool``, True iff every step's logits for that row were finite
    across the whole burst.  The ``isfinite`` reduction rides inside the
    existing scan carry — zero extra dispatches and zero per-token host
    syncs; the caller reads it with the same device->host transfer that
    fetches the tokens.  Returns ``(tokens, cache, ok)`` instead of
    ``(tokens, cache)``.
    """
    sample = temperature > 0.0
    if sample and rng is None:
        raise ValueError("temperature sampling requires an rng key")
    _check_kv_bucket(cfg, kv_bucket)
    full_cache = cache
    if kv_bucket is not None:
        cache = _slice_kv_cache(cache, kv_bucket,
                                keep_extent=_ring_slice_exempt(cfg))

    def select(logits: jax.Array, key) -> jax.Array:
        lg = logits[:, 0, :cfg.vocab_size]
        if sample:
            nxt = jax.random.categorical(key, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32)[:, None]              # [B, 1]

    def step(carry, key):
        tok, c, ok = carry
        logits, c = lm_decode_step(cfg, params, tok, c, kv_repeat=kv_repeat,
                                   shared_kv_repeat=shared_kv_repeat,
                                   moe_groups=moe_groups, rope_len=rope_len)
        if with_sentinel:
            # fold the finiteness reduction into the scan carry: one AND
            # per step on device, surfaced with the tokens' transfer
            ok &= jnp.all(jnp.isfinite(logits[:, 0, :cfg.vocab_size]), -1)
        nxt = select(logits, key)
        return (nxt, c, ok), nxt[:, 0]

    # keys are presplit outside the scan; greedy mode carries none at all
    keys = jax.random.split(rng, n) if sample else None
    ok0 = jnp.ones((first_token.shape[0],), bool)
    (_, cache, ok), toks = jax.lax.scan(
        step, (first_token.astype(jnp.int32), cache, ok0), keys, length=n)
    if kv_bucket is not None:
        cache = _unslice_kv_cache(full_cache, cache)
    if with_sentinel:
        return toks.T, cache, ok                           # [B, n], ..., [B]
    return toks.T, cache                                   # [B, n]


def _is_kv_leaf(path) -> bool:
    """Attention-cache leaves are the dict entries keyed "k"/"v" (possibly
    nested under "attn" for shared blocks); mamba conv/ssm states and "pos"
    never carry those keys."""
    last = path[-1]
    return getattr(last, "key", None) in ("k", "v")


def _check_kv_bucket(cfg: ModelConfig, kv_bucket: Optional[int]) -> None:
    if kv_bucket is None:
        return
    if kv_bucket < 1:
        raise ValueError(f"kv_bucket must be >= 1, got {kv_bucket}")
    if "encoder" in cfg.layer_kinds:
        raise ValueError(
            "kv_bucket requires causal KV caches; encoder (bidirectional) "
            "layers cannot be prefix-sliced")


def _ring_slice_exempt(cfg: ModelConfig) -> Optional[int]:
    """Extent of rolling ring-buffer KV leaves to EXEMPT from bucket
    slicing, or None when they may slice.  With ``REPRO_RING_BUCKETS=1``
    (the default) ring leaves slice like append-only ones — valid because
    callers select ``bucket >= min(max(pos) + chunk, extent)`` from the
    extent-topped ladder, so a ring is only ever sliced before its prefix
    wraps.  The env kill-switch keeps rings at the full window instead."""
    from repro.kernels import dispatch as kdispatch
    if kdispatch.ring_buckets():
        return None
    if "local" in cfg.layer_kinds and cfg.attn is not None:
        return cfg.attn.sliding_window
    return None


def _slice_kv_cache(cache, bucket: int, keep_extent: Optional[int] = None):
    """Slice every KV-cache leaf to its first ``bucket`` rows (axis 2 of the
    stacked [n_rep, B, Skv, KV, hd] leaves).  Callers guarantee every read
    and write of the upcoming program lands below ``bucket``; the masked
    tail contributes exact zeros, so outputs are bit-identical to the
    full-cache program while attention FLOPs/IO track the live prefix.
    For rolling ring-buffer leaves "lands below bucket" additionally means
    the prefix has not wrapped yet — guaranteed by selecting the bucket
    from a ladder that tops out at the model's largest KV extent (window
    for rolling architectures).  ``keep_extent`` exempts leaves of exactly
    that extent (the ``REPRO_RING_BUCKETS=0`` escape hatch) — extent
    matching is deliberately conservative: an append-only leaf that
    coincidentally equals the window is also kept whole, which only costs
    FLOPs in that already-degraded debug mode, never correctness."""
    def f(path, leaf):
        if (_is_kv_leaf(path) and leaf.shape[2] > bucket
                and leaf.shape[2] != keep_extent):
            return jax.lax.slice_in_dim(leaf, 0, bucket, axis=2)
        return leaf
    return jax.tree_util.tree_map_with_path(f, cache)


def _unslice_kv_cache(full, sliced):
    """Write bucket-sliced KV leaves back into the full-extent cache (rows
    past the bucket were untouched by construction)."""
    def f(path, f_leaf, s_leaf):
        if _is_kv_leaf(path) and s_leaf.shape[2] < f_leaf.shape[2]:
            return jax.lax.dynamic_update_slice_in_dim(
                f_leaf, s_leaf.astype(f_leaf.dtype), 0, axis=2)
        return s_leaf
    return jax.tree_util.tree_map_with_path(f, full, sliced)


def _cache_max_seq(cfg: ModelConfig, cache) -> Optional[int]:
    """KV caches are [n_rep, B, S, KV, hd]; mamba caches have no usable seq
    dim, so look for a 5-D leaf (present whenever any layer has attention)."""
    if cache is None:
        return None
    best = None
    for seg in cache["segments"]:
        for leaf in jax.tree_util.tree_leaves(seg):
            if leaf.ndim == 5:
                best = max(best or 0, int(leaf.shape[2]))
    return best
