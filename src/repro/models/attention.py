"""GQA attention: dense / chunked(flash-style) / banded-local, plus decode w/ cache.

All variants are written with *global* array semantics; GSPMD partitions them
according to the activation sharding constraints installed by the step
builder (see distributed/sharding.py).  The chunked path mirrors the Pallas
flash kernel (kernels/flash) and is the lowering used for the CPU dry-run.

Rolling sliding-window ("local") caches are ring buffers of exactly
``window`` slots — slot i holds the newest token with ``pos % window ==
i``.  Decode writes modularly; chunked prefill attends ``[ring | chunk]``
with a per-row ``kv_wrap`` cursor that lets the kernels unroll the ring
in-mask (no rolled copy), then folds the chunk back into the ring with a
deterministic gather.  Every architecture therefore prefills through the
same chunked serving path.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import AttnConfig
from repro.distributed.sharding import constrain
from repro.kernels import dispatch as kdispatch
from repro.kernels.flash.ref import ring_kv_positions
from repro.models.params import ParamDef
from repro.models.norms import head_rms_norm
from repro.models.rope import apply_rope

NEG_INF = -1e30


def _full_seq_attn(q, k, v, a: AttnConfig, *, causal: bool,
                   window: Optional[int],
                   q_offset: Optional[jax.Array] = None,
                   kv_wrap: Optional[jax.Array] = None,
                   ring_len: Optional[int] = None) -> jax.Array:
    """Dispatch the full-sequence core. q: [B,Sq,KV,G,hd]; k,v: [B,Skv,KV,hd].

    ``q_offset`` ([B] int32, or None) shifts the causal mask for chunked
    prefill: query i of row b sits at absolute position q_offset[b] + i
    while keys cover absolute positions [0, Skv).

    ``kv_wrap`` ([B] int32) + static ``ring_len`` switch the first
    ``ring_len`` key slots into a ring buffer with modulus ``window`` and
    per-row write cursor ``kv_wrap`` (slots past ``ring_len`` are the
    in-flight chunk at ``kv_wrap + j - ring_len``) — the layout of a
    chunked prefill over a rolling sliding-window cache."""
    if kdispatch.get_backend() != "ref":
        from repro.kernels.flash.ops import flash_attention
        b, sq, nkv, g, hd = q.shape
        qh = q.reshape(b, sq, nkv * g, hd).transpose(0, 2, 1, 3)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        o = flash_attention(qh, kh, vh, causal=causal, window=window,
                            q_offset=q_offset, kv_wrap=kv_wrap,
                            ring_len=ring_len)
        return o.transpose(0, 2, 1, 3).reshape(b, sq, nkv, g, hd)
    kv_pos = None
    if kv_wrap is not None:
        kv_pos = ring_kv_positions(kv_wrap, window, ring_len, k.shape[1])
    if (q_offset is None and kv_pos is None and window is not None and causal
            and k.shape[1] > 2 * window):
        return _local_banded_attention(q, k, v, window=window)
    off = 0 if q_offset is None else q_offset
    if k.shape[1] <= a.dense_cutoff or a.impl == "dense":
        return _dense_attention(q, k, v, causal=causal, window=window,
                                q_offset=off, kv_pos=kv_pos)
    return _chunked_attention(q, k, v, causal=causal, window=window,
                              q_offset=off, kv_pos=kv_pos)


def attn_param_defs(d_model: int, a: AttnConfig) -> Dict[str, ParamDef]:
    defs = {
        "wq": ParamDef((d_model, a.n_heads, a.head_dim), ("embed", "heads", None),
                       fan_in=d_model),
        "wk": ParamDef((d_model, a.n_kv_heads, a.head_dim), ("embed", "kv_heads", None),
                       fan_in=d_model),
        "wv": ParamDef((d_model, a.n_kv_heads, a.head_dim), ("embed", "kv_heads", None),
                       fan_in=d_model),
        "wo": ParamDef((a.n_heads, a.head_dim, d_model), ("heads", None, "embed"),
                       init="normal_out", fan_in=a.n_heads * a.head_dim),
    }
    if a.qk_norm:
        defs["q_norm"] = ParamDef((a.head_dim,), (None,), init="zeros")
        defs["k_norm"] = ParamDef((a.head_dim,), (None,), init="zeros")
    return defs


def _repeat_kv(k: jax.Array, repeat: int) -> jax.Array:
    if repeat == 1:
        return k
    return jnp.repeat(k, repeat, axis=2)


def _group_q(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,H,hd] -> [B,S,KV,G,hd] grouping q heads by their kv head."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _dense_attention(q, k, v, *, causal: bool, window: Optional[int],
                     q_offset=0, kv_pos=None) -> jax.Array:
    """q: [B,Sq,KV,G,hd]; k,v: [B,Skv,KV,hd]. Returns [B,Sq,KV,G,hd].
    ``q_offset``: scalar or [B] per-row query-position offset.
    ``kv_pos`` ([B, Skv] int32, or None for ``arange``): per-slot absolute
    key positions (negative = never written, masked out) — the ring-buffer
    KV layout of a chunked prefill over a rolling window."""
    with jax.named_scope("attn_core"):
        scale = 1.0 / math.sqrt(q.shape[-1])
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                            preferred_element_type=jnp.float32) * scale
        sq, skv = q.shape[1], k.shape[1]
        off = jnp.atleast_1d(jnp.asarray(q_offset))
        qpos = jnp.arange(sq)[None, :] + off[:, None]          # [Bb, Sq]
        if kv_pos is not None:
            kpos = kv_pos[:, None, :]                          # [B, 1, Skv]
            mask = jnp.broadcast_to(kpos >= 0,
                                    (kv_pos.shape[0], sq, skv))
        else:
            kpos = jnp.arange(skv)[None, None, :]
            mask = jnp.ones((off.shape[0], sq, skv), bool)
        if causal:
            mask = mask & (qpos[:, :, None] >= kpos)
        if window is not None:
            mask = mask & ((qpos[:, :, None] - kpos) < window)
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def _chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                       kv_block: int = 1024, q_offset=0,
                       kv_pos=None) -> jax.Array:
    """Online-softmax over kv blocks (flash-style, numerically exact).
    ``q_offset``: scalar or [B] per-row query-position offset.
    ``kv_pos`` ([B, Skv] int32, or None for ``arange``): per-slot absolute
    key positions (negative = masked), for ring-buffer KV layouts."""
    b, sq, nkv, g, hd = q.shape
    skv = k.shape[1]
    nb = -(-skv // kv_block)
    pad = nb * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, kv_block, nkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, nkv, hd).transpose(1, 0, 2, 3, 4)
    # key positions per slot (-1 on the padded tail so it masks out); the
    # default arange collapses to the classic in-order layout
    if kv_pos is None:
        kv_pos = jnp.arange(skv, dtype=jnp.int32)[None, :]
    kp = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    kpb = kp.reshape(kp.shape[0], nb, kv_block).transpose(1, 0, 2)
    scale = 1.0 / math.sqrt(hd)
    off = jnp.atleast_1d(jnp.asarray(q_offset))
    qpos = jnp.arange(sq)[None, :] + off[:, None]              # [Bb, Sq]
    nrow = max(off.shape[0], kp.shape[0])

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, kpos = blk
        with jax.named_scope("attn_core"):
            s = jnp.einsum("bqkgd,bskd->bkgqs", q, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.broadcast_to(kpos[:, None, :] >= 0,
                                    (nrow, sq, kv_block))
            if causal:
                mask = mask & (qpos[:, :, None] >= kpos[:, None, :])
            if window is not None:
                mask = mask & ((qpos[:, :, None] - kpos[:, None, :]) < window)
            s = jnp.where(mask[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), vblk)
            acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, nkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, nkv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-37).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype)


def _local_banded_attention(q, k, v, *, window: int) -> jax.Array:
    """Sliding-window causal attention via the two-block trick (exact for
    window <= block).  FLOPs ~ S * 2w instead of S^2."""
    b, sq, nkv, g, hd = q.shape
    w = window
    nb = -(-sq // w)
    pad = nb * w - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nb, w, nkv, g, hd)
    kb = k.reshape(b, nb, w, nkv, hd)
    vb = v.reshape(b, nb, w, nkv, hd)
    kprev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vprev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kprev, kb], axis=2)  # [B, nb, 2w, KV, hd]
    v2 = jnp.concatenate([vprev, vb], axis=2)
    with jax.named_scope("attn_core"):
        scale = 1.0 / math.sqrt(hd)
        s = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2,
                       preferred_element_type=jnp.float32) * scale
        qpos = jnp.arange(w)[:, None] + w          # position within [prev, own]
        kpos = jnp.arange(2 * w)[None, :]
        mask = (qpos >= kpos) & ((qpos - kpos) < w)
        # first block has no previous block
        first = (kpos >= w) & mask
        blk = jnp.arange(nb)
        mask_b = jnp.where((blk == 0)[:, None, None], first[None], mask[None])
        s = jnp.where(mask_b[None, :, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        ob = jnp.einsum("bnkgqs,bnskd->bnqkgd", p, v2)
    out = ob.reshape(b, nb * w, nkv, g, hd)
    return out[:, :sq]


def attention(p: Dict, x: jax.Array, a: AttnConfig, *,
              rope: Optional[Tuple[jax.Array, jax.Array]],
              window: Optional[int] = None,
              cache: Optional[Dict] = None,
              pos: Optional[jax.Array] = None,
              kv_repeat: int = 1,
              chunk_mask: Optional[jax.Array] = None,
              eps: float = 1e-6) -> Tuple[jax.Array, Optional[Dict]]:
    """Full attention sub-block: qkv proj -> rope -> core -> out proj.

    cache=None: full-sequence (train/prefill, no cache returned).
    cache dict with "k","v" [B,Skv,KV*rep,hd]: if x has S>1 it is a prefill
    that fills the cache; if S==1 it is a decode step at position ``pos``.

    ``chunk_mask`` ([B, S] bool, chunked prefill only) marks the valid
    prefix of the chunk per row; rolling (ring-buffer) caches use it to
    gate their writes — an invalid token must never overwrite live ring
    history (append-only caches just let later writes/masks hide it).
    """
    b, s, _ = x.shape
    with jax.named_scope("qkv_proj"):
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if a.qk_norm:
        q = head_rms_norm(q, p["q_norm"], eps)
        k = head_rms_norm(k, p["k_norm"], eps)
    if rope is not None:
        sin, cos = rope
        if cache is not None and s == 1:
            # per-row positions: pos is [B] (scalar broadcasts for old callers)
            posv = jnp.broadcast_to(jnp.atleast_1d(pos), (b,))
            # clip like the dynamic_slice this replaces (an overrun row —
            # e.g. a retired engine slot — must stay finite, not NaN-fill)
            sin = jnp.take(sin, posv, axis=0, mode="clip")[:, None]
            cos = jnp.take(cos, posv, axis=0, mode="clip")[:, None]
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)
        elif cache is not None and pos is not None:
            # chunked prefill: row b's chunk covers absolute positions
            # pos[b] .. pos[b]+s (clip keeps overrun/inert rows finite)
            posv = jnp.broadcast_to(jnp.atleast_1d(pos), (b,))
            idx = posv[:, None] + jnp.arange(s)                # [B, s]
            sin = jnp.take(sin, idx, axis=0, mode="clip")      # [B, s, half]
            cos = jnp.take(cos, idx, axis=0, mode="clip")
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)
        else:
            q = apply_rope(q, sin[:s], cos[:s])
            k = apply_rope(k, sin[:s], cos[:s])
    # the cache stores UNREPEATED kv heads (exact GQA); replication to a
    # shardable head count happens at compute time only.
    kr = constrain(_repeat_kv(k, kv_repeat), ("batch", "seq", "kv_heads", None))
    vr = constrain(_repeat_kv(v, kv_repeat), ("batch", "seq", "kv_heads", None))
    n_kv = a.n_kv_heads * kv_repeat
    q = constrain(_group_q(q, n_kv), ("batch", "seq", "kv_heads", None, None))

    new_cache = None
    if cache is None:
        o = _full_seq_attn(q, kr, vr, a, causal=a.causal, window=window)
    elif (s > 1 and pos is not None and window is not None
          and cache["k"].shape[1] <= window):
        # ring-buffer chunked prefill over a rolling sliding-window cache:
        # attend the chunk against [ring | chunk] with the modular mask
        # (the kernels unroll the ring via kv_wrap — no rolled copy), then
        # fold the chunk's last min(len, window) valid tokens back into the
        # ring at slot (pos + i) % window.  ``ring_len`` may be < window
        # when the serving layer bucket-sliced a not-yet-wrapped ring.
        ring_len = cache["k"].shape[1]
        posv = jnp.broadcast_to(jnp.atleast_1d(pos), (b,)).astype(jnp.int32)
        if chunk_mask is not None:
            chunk_len = jnp.sum(chunk_mask, axis=1).astype(jnp.int32)
        else:
            chunk_len = jnp.full((b,), s, jnp.int32)
        kcat = jnp.concatenate([cache["k"].astype(k.dtype), k], axis=1)
        vcat = jnp.concatenate([cache["v"].astype(v.dtype), v], axis=1)
        kcr = constrain(_repeat_kv(kcat, kv_repeat),
                        ("batch", "kv_seq", "kv_heads", None))
        vcr = constrain(_repeat_kv(vcat, kv_repeat),
                        ("batch", "kv_seq", "kv_heads", None))
        o = _full_seq_attn(q, kcr, vcr, a, causal=a.causal, window=window,
                           q_offset=posv, kv_wrap=posv, ring_len=ring_len)
        # ring write as a gather: slot j takes the LAST valid chunk token
        # with (pos + i) % window == j, or keeps its old row.  (A scatter
        # would rely on XLA's unspecified duplicate-index ordering when
        # chunk > window; the gather is deterministic by construction.)
        slot = jnp.arange(ring_len, dtype=jnp.int32)

        def _ring_write(ring, upd, p, ln):
            t = jnp.mod(p + ln - 1 - slot, window)
            i = ln - 1 - t                       # largest valid source idx
            src = jnp.take(upd, jnp.clip(i, 0, s - 1), axis=0)
            return jnp.where((i >= 0)[:, None, None],
                             src.astype(ring.dtype), ring)

        kc = constrain(jax.vmap(_ring_write)(cache["k"], k, posv, chunk_len),
                       ("batch", "kv_seq", "kv_heads", None))
        vc = constrain(jax.vmap(_ring_write)(cache["v"], v, posv, chunk_len),
                       ("batch", "kv_seq", "kv_heads", None))
        new_cache = {"k": kc, "v": vc}
    elif s > 1 and pos is not None:
        # chunked prefill: scatter this chunk's kv at each row's running
        # offset, then attend over the whole cache with the offset causal
        # mask.  Out-of-range positions are dropped; positions past a row's
        # valid length hold garbage that the next chunk overwrites or the
        # decode-time valid_len mask hides.
        skv = cache["k"].shape[1]
        posv = jnp.broadcast_to(jnp.atleast_1d(pos), (b,)).astype(jnp.int32)
        idx = posv[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]

        def _scatter_rows(full, upd, ii):
            return full.at[ii].set(upd.astype(full.dtype), mode="drop")

        kc = constrain(jax.vmap(_scatter_rows)(cache["k"], k, idx),
                       ("batch", "kv_seq", "kv_heads", None))
        vc = constrain(jax.vmap(_scatter_rows)(cache["v"], v, idx),
                       ("batch", "kv_seq", "kv_heads", None))
        new_cache = {"k": kc, "v": vc}
        kcr = constrain(_repeat_kv(kc.astype(x.dtype), kv_repeat),
                        ("batch", "kv_seq", "kv_heads", None))
        vcr = constrain(_repeat_kv(vc.astype(x.dtype), kv_repeat),
                        ("batch", "kv_seq", "kv_heads", None))
        o = _full_seq_attn(q, kcr, vcr, a, causal=a.causal, window=window,
                           q_offset=posv)
    elif s > 1:
        # prefill into cache
        o = _full_seq_attn(q, kr, vr, a, causal=a.causal, window=window)
        skv = cache["k"].shape[1]
        if window is not None and skv == window:
            # rolling cache: slot i must hold the token with pos % window == i
            # (decode writes at pos % window), so roll the last-window slice.
            if s >= window:
                kw, vw = k[:, -window:], v[:, -window:]
                shift = (s - window) % window
                kw = jnp.roll(kw, shift, axis=1)
                vw = jnp.roll(vw, shift, axis=1)
            else:
                kw = jnp.pad(k, ((0, 0), (0, window - s), (0, 0), (0, 0)))
                vw = jnp.pad(v, ((0, 0), (0, window - s), (0, 0), (0, 0)))
            new_cache = {"k": kw.astype(cache["k"].dtype),
                         "v": vw.astype(cache["v"].dtype)}
        else:
            # match the cache layout before the write (kv_seq may be
            # sequence-sharded when kv heads don't divide the model axis)
            kw = constrain(k.astype(cache["k"].dtype),
                           ("batch", "kv_seq", "kv_heads", None))
            vw = constrain(v.astype(cache["v"].dtype),
                           ("batch", "kv_seq", "kv_heads", None))
            kfull = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kw, 0, axis=1)
            vfull = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vw, 0, axis=1)
            new_cache = {"k": kfull, "v": vfull}
    else:
        # decode step: each batch row writes its new kv at its own position
        # (pos: [B] per-slot counters; scalar pos broadcasts).  Per-row
        # dynamic-slice write so the token touches one cache row, not the
        # whole [Skv] axis; out-of-range rows (retired slots) rewrite their
        # clamped row with its current value, i.e. write nothing.
        skv = cache["k"].shape[1]
        posv = jnp.broadcast_to(jnp.atleast_1d(pos), (b,))
        slot = posv % skv if (window is not None and skv == window) else posv
        ok = (slot >= 0) & (slot < skv)
        slot_c = jnp.clip(slot, 0, skv - 1)

        def _write_row(full, new, start, keep):
            cur = jax.lax.dynamic_slice_in_dim(full, start, 1, axis=0)
            return jax.lax.dynamic_update_slice_in_dim(
                full, jnp.where(keep, new, cur), start, axis=0)

        kc = jax.vmap(_write_row)(cache["k"], k.astype(cache["k"].dtype),
                                  slot_c, ok)
        vc = jax.vmap(_write_row)(cache["v"], v.astype(cache["v"].dtype),
                                  slot_c, ok)
        kc = constrain(kc, ("batch", "kv_seq", "kv_heads", None))
        vc = constrain(vc, ("batch", "kv_seq", "kv_heads", None))
        new_cache = {"k": kc, "v": vc}
        # keep the (possibly sequence-sharded) cache layout through the
        # attention compute: with one query token, GSPMD then runs
        # flash-decode split-S (partial softmax stats + tiny psum) instead
        # of all-gathering the cache to match head sharding.
        kcr = constrain(_repeat_kv(kc.astype(x.dtype), kv_repeat),
                        ("batch", "kv_seq", "kv_heads", None))
        vcr = constrain(_repeat_kv(vc.astype(x.dtype), kv_repeat),
                        ("batch", "kv_seq", "kv_heads", None))
        # all backends route through the flash-decode entry point (the ref
        # backend dispatches to the dense oracle inside).  valid_len clamps
        # to skv, which for rolling caches equals the window — every slot of
        # a wrapped rolling cache is live, partially-filled caches mask the
        # unwritten tail.
        from repro.kernels.attn_decode.ops import decode_attention
        bq, _, nkv_, g_, hd_ = q.shape
        qh = q.reshape(bq, nkv_ * g_, hd_)
        valid = jnp.minimum(posv + 1, skv)
        o = decode_attention(qh, kcr.transpose(0, 2, 1, 3),
                             vcr.transpose(0, 2, 1, 3),
                             valid_len=valid)
        o = o.reshape(bq, 1, nkv_, g_, hd_)

    o = o.reshape(b, s, a.n_heads, a.head_dim)
    with jax.named_scope("o_proj"):
        y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return constrain(y, ("batch", "seq", "embed")), new_cache


def init_attn_cache(a: AttnConfig, batch: int, max_seq: int, *,
                    kv_repeat: int = 1, window: Optional[int] = None,
                    dtype=jnp.bfloat16) -> Dict:
    # kv_repeat intentionally ignored: the cache always stores the exact
    # (unreplicated) kv heads; replication happens at compute time.
    del kv_repeat
    # Rolling sliding-window caches are always the FULL window, even when
    # max_seq < window: the rolling invariant (slot i holds the token with
    # pos % window == i) needs all window slots, otherwise decode writes
    # past a clamped cache end are silently dropped and attention goes
    # stale the moment pos crosses the clamp.
    skv = window if window is not None else max_seq
    shape = (batch, skv, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
