"""Per-layer block composition: param defs, cache init, and application."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models import mamba1 as m1
from repro.models import mamba2 as m2
from repro.models.attention import attention, attn_param_defs, init_attn_cache
from repro.models.mlp import mlp, mlp_param_defs
from repro.models.moe import moe, moe_param_defs
from repro.models.norms import rms_norm
from repro.models.params import ParamDef

ATTN_KINDS = ("dense", "local", "encoder", "moe", "dense_moe")
MAMBA_KINDS = ("mamba2", "mamba2+shared", "mamba1")


def layer_param_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    D = cfg.d_model
    if kind in ("dense", "local", "encoder", "dense_moe"):
        return {
            "ln1": ParamDef((D,), ("embed",), init="zeros"),
            "attn": attn_param_defs(D, cfg.attn),
            "ln2": ParamDef((D,), ("embed",), init="zeros"),
            "mlp": mlp_param_defs(D, cfg.d_ff),
        }
    if kind == "moe":
        return {
            "ln1": ParamDef((D,), ("embed",), init="zeros"),
            "attn": attn_param_defs(D, cfg.attn),
            "ln2": ParamDef((D,), ("embed",), init="zeros"),
            "moe": moe_param_defs(D, cfg.moe),
        }
    if kind == "hybrid_par":
        return {
            "ln1": ParamDef((D,), ("embed",), init="zeros"),
            "attn": attn_param_defs(D, cfg.attn),
            "mamba": m2.mamba2_param_defs(D, cfg.ssm),
            "ln2": ParamDef((D,), ("embed",), init="zeros"),
            "mlp": mlp_param_defs(D, cfg.d_ff),
        }
    if kind in ("mamba2", "mamba2+shared"):
        return {
            "ln": ParamDef((D,), ("embed",), init="zeros"),
            "mamba": m2.mamba2_param_defs(D, cfg.ssm),
        }
    if kind == "mamba1":
        return {
            "ln": ParamDef((D,), ("embed",), init="zeros"),
            "mamba": m1.mamba1_param_defs(D, cfg.ssm),
        }
    raise ValueError(f"unknown layer kind {kind!r}")


def shared_block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """Zamba2-style shared transformer block (one copy, applied at every
    'mamba2+shared' position)."""
    D = cfg.d_model
    a = cfg.shared_attn
    return {
        "ln1": ParamDef((D,), ("embed",), init="zeros"),
        "attn": attn_param_defs(D, a),
        "ln2": ParamDef((D,), ("embed",), init="zeros"),
        "mlp": mlp_param_defs(D, cfg.shared_attn_d_ff or cfg.d_ff),
    }


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     *, kv_repeat: int = 1, shared_kv_repeat: int = 1,
                     dtype=jnp.bfloat16) -> Optional[Dict]:
    if kind == "encoder":
        return {}
    if kind in ("dense", "moe", "dense_moe", "local"):
        window = cfg.attn.sliding_window if kind == "local" else None
        return init_attn_cache(cfg.attn, batch, max_seq, kv_repeat=kv_repeat,
                               window=window, dtype=dtype)
    if kind == "hybrid_par":
        c = m2.init_mamba2_cache(cfg.d_model, cfg.ssm, batch, dtype)
        c.update(init_attn_cache(cfg.attn, batch, max_seq,
                                 kv_repeat=kv_repeat, dtype=dtype))
        return c
    if kind == "mamba2":
        return m2.init_mamba2_cache(cfg.d_model, cfg.ssm, batch, dtype)
    if kind == "mamba2+shared":
        c = m2.init_mamba2_cache(cfg.d_model, cfg.ssm, batch, dtype)
        c["attn"] = init_attn_cache(cfg.shared_attn, batch, max_seq,
                                    kv_repeat=shared_kv_repeat, dtype=dtype)
        return c
    if kind == "mamba1":
        return m1.init_mamba1_cache(cfg.d_model, cfg.ssm, batch, dtype)
    raise ValueError(kind)


def _residual(x: jax.Array) -> jax.Array:
    """Sequence-parallel residual stream (no-op unless the plan enables
    the residual_seq rule)."""
    from repro.distributed.sharding import constrain
    return constrain(x, ("batch", "residual_seq", "embed"))


def apply_layer(cfg: ModelConfig, kind: str, p: Dict, x: jax.Array, *,
                rope, rope_local=None, cache: Optional[Dict] = None,
                pos: Optional[jax.Array] = None, kv_repeat: int = 1,
                shared: Optional[Dict] = None, shared_kv_repeat: int = 1,
                moe_groups: int = 1,
                chunk_mask: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """``chunk_mask`` ([B, S] bool) marks valid tokens during a chunked
    prefill (cache + S>1 + pos): attention offsets its causal mask / KV
    writes by ``pos``, SSM layers treat invalid tokens as inert."""
    eps = cfg.norm_eps
    x = _residual(x)
    if kind in ATTN_KINDS:
        window = cfg.attn.sliding_window if kind == "local" else None
        rt = rope_local if (kind == "local" and rope_local is not None) else rope
        h = rms_norm(x, p["ln1"], eps)
        attn_cache = cache if (cache is None or "k" in cache) else None
        a_out, new_attn_cache = attention(
            p["attn"], h, cfg.attn, rope=rt, window=window,
            cache=attn_cache if kind != "encoder" else None,
            pos=pos, kv_repeat=kv_repeat, chunk_mask=chunk_mask, eps=eps)
        x = x + a_out
        h = rms_norm(x, p["ln2"], eps)
        if kind == "moe":
            x = x + moe(p["moe"], h, cfg.moe, moe_groups, cfg.act)
        else:
            x = x + mlp(p["mlp"], h, cfg.act)
        new_cache = new_attn_cache if kind != "encoder" else {}
        return _residual(x), new_cache

    if kind == "hybrid_par":
        # Falcon-H1-style parallel hybrid heads: attention + SSM branches
        # read the same normed input; outputs sum into the residual.
        h = rms_norm(x, p["ln1"], eps)
        attn_cache = ({"k": cache["k"], "v": cache["v"]}
                      if cache is not None else None)
        a_out, new_attn = attention(p["attn"], h, cfg.attn, rope=rope,
                                    cache=attn_cache, pos=pos,
                                    kv_repeat=kv_repeat,
                                    chunk_mask=chunk_mask, eps=eps)
        mcache = ({"conv": cache["conv"], "ssm": cache["ssm"]}
                  if cache is not None else None)
        is_decode = cache is not None and x.shape[1] == 1 and pos is not None
        if is_decode:
            m_out, new_m = m2.mamba2_decode(p["mamba"], h, cfg.ssm,
                                            cfg.d_model, cache=mcache, eps=eps)
        else:
            m_out, new_m = m2.mamba2_block(p["mamba"], h, cfg.ssm,
                                           cfg.d_model, cache=mcache, eps=eps,
                                           mask=chunk_mask)
        x = x + a_out + m_out
        h = rms_norm(x, p["ln2"], eps)
        x = x + mlp(p["mlp"], h, cfg.act)
        new_cache = None
        if cache is not None:
            new_cache = dict(new_m or {})
            if new_attn is not None:
                new_cache.update(new_attn)
        return _residual(x), new_cache

    if kind in ("mamba2", "mamba2+shared"):
        h = rms_norm(x, p["ln"], eps)
        mcache = None
        if cache is not None:
            mcache = {"conv": cache["conv"], "ssm": cache["ssm"]}
        is_decode = cache is not None and x.shape[1] == 1 and pos is not None
        if is_decode:
            out, new_m = m2.mamba2_decode(p["mamba"], h, cfg.ssm, cfg.d_model,
                                          cache=mcache, eps=eps)
        else:
            out, new_m = m2.mamba2_block(p["mamba"], h, cfg.ssm, cfg.d_model,
                                         cache=mcache, eps=eps,
                                         mask=chunk_mask)
        x = x + out
        new_cache = new_m
        if kind == "mamba2+shared":
            assert shared is not None, "shared block params required"
            h = rms_norm(x, shared["ln1"], eps)
            a_out, new_shared_cache = attention(
                shared["attn"], h, cfg.shared_attn, rope=rope,
                cache=cache["attn"] if cache is not None else None,
                pos=pos, kv_repeat=shared_kv_repeat,
                chunk_mask=chunk_mask, eps=eps)
            x = x + a_out
            h = rms_norm(x, shared["ln2"], eps)
            x = x + mlp(shared["mlp"], h, cfg.act)
            if new_cache is not None:
                new_cache = dict(new_cache)
                new_cache["attn"] = new_shared_cache
        return _residual(x), new_cache

    if kind == "mamba1":
        h = rms_norm(x, p["ln"], eps)
        is_decode = cache is not None and x.shape[1] == 1 and pos is not None
        if is_decode:
            out, new_m = m1.mamba1_decode(p["mamba"], h, cfg.ssm, cfg.d_model,
                                          cache=cache, eps=eps)
        else:
            out, new_m = m1.mamba1_block(p["mamba"], h, cfg.ssm, cfg.d_model,
                                         cache=cache, eps=eps,
                                         mask=chunk_mask)
        return _residual(x + out), new_m

    raise ValueError(kind)
