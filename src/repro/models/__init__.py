from repro.models.lm import (  # noqa: F401
    decode_tokens, init_lm_cache, init_lm_params, lm_decode_step, lm_forward,
    lm_param_axes, lm_prefill, lm_prefill_chunk, model_param_defs,
)
